"""Training substrate: optimizers, train step, checkpointing."""

from repro.train.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import OptConfig, opt_init, opt_update
from repro.train.trainer import TrainState, init_state, make_train_step

__all__ = [
    "OptConfig",
    "TrainState",
    "init_state",
    "latest_step",
    "make_train_step",
    "opt_init",
    "opt_update",
    "restore_checkpoint",
    "save_checkpoint",
]
