"""Optimizers: AdamW and (factored) Adafactor, pytree-native.

AdamW is the default.  Adafactor (factored second moment, no first moment)
is selected for the very largest archs (jamba-398b) where Adam's 8 bytes of
state per parameter cannot fit a 256-chip pod (DESIGN.md SS6) — the
PaLM/T5 production trade-off.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # "adamw" | "adafactor"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup: int = 100


def _lr_at(cfg: OptConfig, step: Array) -> Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
    return cfg.lr * warm


def global_norm(tree: Any) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: Any) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"mu": jax.tree.map(zeros, params), "nu": jax.tree.map(zeros, params)}


def adamw_update(
    params: Any, grads: Any, state: dict[str, Any], cfg: OptConfig, step: Array
) -> tuple[Any, dict[str, Any]]:
    lr = _lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + wd)
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, {"mu": new_m, "nu": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, momentum-free)
# ---------------------------------------------------------------------------

def adafactor_init(params: Any) -> dict[str, Any]:
    def stats(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"stats": jax.tree.map(stats, params, is_leaf=lambda x: isinstance(x, jax.Array) or hasattr(x, "shape"))}


def adafactor_update(
    params: Any, grads: Any, state: dict[str, Any], cfg: OptConfig, step: Array
) -> tuple[Any, dict[str, Any]]:
    lr = _lr_at(cfg, step)
    beta2 = 1.0 - (step + 1.0) ** -0.8     # schedule from the paper
    eps = 1e-30

    def upd(p, g, st):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            v = rfac[..., None] * vc[..., None, :]
            nst = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            nst = {"v": v}
        u = g / jnp.sqrt(jnp.maximum(v, eps))
        # update clipping (RMS <= 1) per the Adafactor paper
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + wd)
        return newp.astype(p.dtype), nst

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    stats_list = tdef.flatten_up_to(state["stats"])
    outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, stats_list)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_s = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_p, {"stats": new_s}


# ---------------------------------------------------------------------------

def opt_init(params: Any, cfg: OptConfig) -> dict[str, Any]:
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params)
    raise ValueError(cfg.name)


def opt_update(
    params: Any, grads: Any, state: dict[str, Any], cfg: OptConfig, step: Array
) -> tuple[Any, dict[str, Any]]:
    if cfg.grad_clip:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.name == "adamw":
        return adamw_update(params, grads, state, cfg, step)
    if cfg.name == "adafactor":
        return adafactor_update(params, grads, state, cfg, step)
    raise ValueError(cfg.name)
