"""Fault-tolerant checkpointing: atomic, portable, reshard-on-restore.

Format: one directory per step containing
  * ``manifest.json`` — step, mesh shape, rng, data-pipeline cursor, and the
    flattened tree structure with per-leaf dtype/shape;
  * ``arrays.npz`` — the leaves (gathered to host).

Guarantees needed at 1000+ nodes (DESIGN.md SS6):
  * **atomicity**: written to ``<dir>.tmp`` then ``os.rename``d — a job
    killed mid-write can never leave a half checkpoint that restore picks;
  * **elasticity**: restore takes the *current* mesh + shardings and
    device_puts each leaf accordingly — the saving and restoring meshes may
    differ (elastic scale-up/down, straggler-evicted hosts);
  * **retention**: ``keep`` newest checkpoints are retained, best-effort GC.

On a real multi-host pod the np.asarray gather becomes a per-host shard
write (tensorstore-style); the single-host container exercises the same
code path end to end.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _flatten_with_names(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return named, tdef


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    *,
    extra: dict[str, Any] | None = None,
    keep: int = 3,
) -> str:
    """Atomically write ``state`` (any pytree) for ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named, _ = _flatten_with_names(state)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(named)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "names": [n for n, _ in named],
        "shapes": [list(np.shape(l)) for _, l in named],
        "dtypes": [str(np.asarray(l).dtype) for _, l in named],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in ckpts[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    state_like: Any,
    *,
    step: int | None = None,
    shardings: Any | None = None,
) -> tuple[Any, dict[str, Any]]:
    """Restore into the structure of ``state_like``; reshard if ``shardings``
    (a matching pytree of NamedSharding) is given — the elastic-restart path.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    named, tdef = _flatten_with_names(state_like)
    saved_names = manifest["names"]
    assert [n for n, _ in named] == saved_names, "tree structure mismatch"
    leaves = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    for i, (name, like) in enumerate(named):
        arr = data[f"a{i}"]
        if hasattr(like, "dtype"):
            arr = arr.astype(like.dtype)
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, leaves), manifest["extra"]
