"""Training loop: train-step builder, gradient accumulation, compression.

The step is a pure jittable function; distribution comes from the shardings
attached to its inputs (launch/dryrun.py, launch/train.py).  Gradient
accumulation scans microbatches and averages grads *before* the optimizer
(compute/comm overlap: with DP over (pod, data), GSPMD schedules the
cross-replica reduce of each microbatch's grads concurrently with the next
microbatch's backward).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.compression import CompressionConfig, compress_grads
from repro.models.model import LM
from repro.train.optimizer import OptConfig, opt_init, opt_update

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: Array
    params: Any
    opt: Any
    err: Any = None          # error-feedback state for compressed grads


def init_state(model: LM, rng, opt_cfg: OptConfig,
               comp: CompressionConfig | None = None) -> TrainState:
    params = model.init(rng)
    opt = opt_init(params, opt_cfg)
    err = (
        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        if comp is not None and comp.error_feedback
        else None
    )
    return TrainState(step=jnp.zeros((), jnp.int32), params=params, opt=opt, err=err)


def make_train_step(
    model: LM,
    opt_cfg: OptConfig,
    *,
    grad_accum: int = 1,
    compression: CompressionConfig | None = None,
) -> Callable[[TrainState, dict[str, Array]], tuple[TrainState, dict[str, Array]]]:
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``batch`` leaves have leading dim ``global_batch``; with grad_accum > 1
    they are reshaped to (accum, global_batch / accum, ...) and scanned.
    """

    def loss_of(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state: TrainState, batch: dict[str, Array]):
        if grad_accum > 1:
            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(state.params, mb)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = lax.scan(acc_body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {"ce": loss, "aux": jnp.zeros(())}
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        err = state.err
        if compression is not None:
            grads, err = compress_grads(grads, err, compression)

        params, opt = opt_update(
            state.params, grads, state.opt, opt_cfg, state.step
        )
        new_state = TrainState(
            step=state.step + 1, params=params, opt=opt, err=err
        )
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
