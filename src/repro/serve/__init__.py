"""Serving substrate: batched autoregressive decode on top of LM caches."""

from repro.serve.decode import DecodeSession, greedy_decode

__all__ = ["DecodeSession", "greedy_decode"]
