"""Batched autoregressive decoding on top of the LM cache machinery.

``DecodeSession`` owns jitted prefill/step functions and per-batch cache
state — the serving inner loop the ``decode_*`` dry-run shapes lower.
Weight-stationary serve sharding (DESIGN.md SS6 / SSPerf hillclimb 2) is a
property of the shardings attached to ``params``, not of this code.

Serving checklist (applies equally to a search deployment — see
search/guards.py): before a process takes traffic, run the preflight
self-tests against the compiled paths it will serve from —
``build_index(..., preflight=True)`` for the single-device engine,
``preflight_shard_map(mesh, ...)`` (or simply
``make_distributed_search(..., jit="auto")``) for the sharded step — and
admit inputs through the hygiene boundary (``sanitize=`` on
``build_index`` / ``nn_search``) rather than trusting upstream data.  The
runtime guards then stay default-on; a tripped guard degrades to the
reference path instead of serving a silently wrong answer.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import LM

Array = jax.Array


@dataclasses.dataclass
class DecodeSession:
    model: LM
    params: Any
    max_len: int

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len=self.max_len)
        )
        self._step = jax.jit(self.model.decode_step)
        self.caches = None
        self.index = None

    def prefill(self, batch: dict[str, Array]) -> Array:
        logits, self.caches, self.index = self._prefill(self.params, batch)
        return logits

    def step(self, tokens: Array) -> Array:
        """Feed (B, 1) tokens; returns (B, V) next-token logits."""
        logits, self.caches = self._step(
            self.params, self.caches, tokens, self.index
        )
        self.index = self.index + 1
        return logits


def greedy_decode(
    model: LM, params: Any, prompt: Array, n_steps: int
) -> Array:
    """Greedy continuation of ``prompt`` (B, S) for ``n_steps`` tokens."""
    sess = DecodeSession(model, params, max_len=prompt.shape[1] + n_steps)
    logits = sess.prefill({"tokens": prompt})
    toks = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
    for _ in range(n_steps - 1):
        logits = sess.step(toks[-1])
        toks.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    return jnp.concatenate(toks, axis=1)
