"""Pallas TPU kernel: banded DTW, band-packed lane-parallel wavefront.

This is the cascade's expensive verification step (paper Eq. 1-2 with the
Sakoe-Chiba window).  GPU DTW implementations put one *pair* per thread
block and wavefront within the matrix; the TPU-native layout is the
transpose (DESIGN.md SS3): a *batch of pairs* fills the sublanes and the DP
sweeps anti-diagonals sequentially with no data-dependent control flow.

Band-packed state (the O(L*W) rewrite): a DP cell is addressed by its
anti-diagonal ``d = i + j`` and diagonal offset ``k = i - j + w``; the state
per anti-diagonal is a dense ``(TP, Wb)`` block with ``Wb = 2w + 1`` rounded
up to the 128-lane multiple — *not* the ``(TP, L)`` full-width wavefront the
seed kernel swept.  The recurrence is pure lane shifts:

    S_d[k] = cost(i, j) + min(S_{d-1}[k-1], S_{d-1}[k+1], S_{d-2}[k])

with ``i = (d + k - w)/2`` (cells exist only at matching parity).  The cost
operands are *contiguous* slices of the 2x-duplicated series
``A2[t] = a[t//2]`` and the flipped duplicate of ``b`` — both packed on the
host, so each of the ``2L - 1`` steps is two ``dynamic_slice`` calls plus a
handful of ``(TP, Wb)`` VPU ops.  Per-pair work and state drop from O(L^2)
to O(L * Wb): ~10x fewer FLOPs at the paper's w = 0.1L.

Early abandon (PrunedDTW-style, arXiv:2102.05221): every warping path
crosses anti-diagonal ``d`` or ``d-1`` and prefix costs only grow, so
``min(S_d, S_{d-1})`` per pair lower-bounds its final DTW.  Rows whose
frontier minimum exceeds their ``cutoff`` are poisoned to +inf and ride the
remaining steps as dead lanes, returning +inf.

VMEM budget (per grid step): packed operands a2p + b2p are
``2 * TP * pad_len`` f32 with ``pad_len ~= 2L + Wb``, plus 2 state buffers
and ~4 temporaries of ``TP * Wb`` — ``(4L + ~8Wb) * TP * 4`` bytes.  TP=128,
L=2048, w=205 (0.1L, Wb=512): ~6.2 MB.  ``tile_p`` auto-shrinks (multiples
of 8) to keep long series inside ``_VMEM_BUDGET``, which is what lets
``_DTW_MAX_L`` in ops.py rise from 4096 to 16384 (L=16384, small w -> TP=32,
~8.6 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

_INF = float(jnp.inf)
_VMEM_BUDGET = 10 * 2**20          # bytes for packed operands + DP state


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _dtw_band_kernel(a2p_ref, b2p_ref, cut_ref, out_ref, *, L: int, w: int,
                     Wb: int):
    a2p = a2p_ref[...]                                   # (TP, pad_len)
    b2p = b2p_ref[...]
    cut = cut_ref[...][:, None]                          # (TP, 1)
    tp = a2p.shape[0]
    dt = a2p.dtype
    kk = lax.broadcasted_iota(jnp.int32, (tp, Wb), 1)

    def step(d, carry):
        d1, d2 = carry                                   # S_{d-1}, S_{d-2}
        a_at = lax.dynamic_slice(a2p, (0, d), (tp, Wb))  # a[(d + k - w)//2]
        b_at = lax.dynamic_slice(b2p, (0, 2 * L - 1 - d), (tp, Wb))
        diff = a_at - b_at
        cost = diff * diff
        inf_col = jnp.full((tp, 1), _INF, dt)
        dep_l = jnp.concatenate([inf_col, d1[:, :-1]], axis=-1)  # S_{d-1}[k-1]
        dep_r = jnp.concatenate([d1[:, 1:], inf_col], axis=-1)   # S_{d-1}[k+1]
        best = jnp.minimum(jnp.minimum(dep_l, dep_r), d2)
        origin = (d == 0) & (kk == w)
        nd = cost + jnp.where(origin, 0.0, best)
        t = d + kk - w                                   # 2i
        s = d - kk + w                                   # 2j
        valid = ((t & 1) == 0) & (t >= 0) & (t <= 2 * L - 2) \
            & (s >= 0) & (s <= 2 * L - 2) & (kk <= 2 * w)
        nd = jnp.where(valid, nd, _INF)
        # every path crosses diagonal d or d-1 -> frontier min is a LB
        fmin = jnp.min(jnp.minimum(nd, d1), axis=-1, keepdims=True)
        dead = fmin > cut
        nd = jnp.where(dead, _INF, nd)
        d1 = jnp.where(dead, _INF, d1)
        return nd, d1

    init = (jnp.full((tp, Wb), _INF, dt), jnp.full((tp, Wb), _INF, dt))
    dlast, _ = lax.fori_loop(0, 2 * L - 1, step, init)
    out_ref[...] = dlast[:, w]


@functools.partial(
    jax.jit, static_argnames=("w", "tile_p", "interpret")
)
def dtw_band_pallas(
    a: Array,
    b: Array,
    w: int | None = None,
    cutoff: Array | None = None,
    *,
    tile_p: int = 128,
    interpret: bool = False,
) -> Array:
    """Pairwise banded DTW: ``(P, L), (P, L) -> (P,)`` squared-cost values.

    ``cutoff`` is an optional per-pair ``(P,)`` early-abandon threshold:
    pairs whose true distance is strictly below their cutoff return the
    exact value; others return ``>= cutoff`` (normally +inf).
    """
    P, L = a.shape
    if w is None or w >= L:
        w = L
    wb = min(w, L - 1)                 # |i - j| <= L - 1 always holds
    Wb = _round_up(2 * wb + 1, 128)
    pad_len = _round_up(2 * L + Wb + wb, 128)
    # auto-shrink the pair tile so packed operands + state fit VMEM
    per_row = (2 * pad_len + 8 * Wb) * 4
    tile_p = min(tile_p, max(8, (_VMEM_BUDGET // per_row) // 8 * 8))
    tile_p = min(tile_p, _round_up(P, 8))
    if cutoff is None:
        cutoff = jnp.full((P,), _INF, a.dtype)
    else:
        cutoff = jnp.broadcast_to(jnp.asarray(cutoff, a.dtype), (P,))
    pp = (-P) % tile_p
    if pp:
        a = jnp.pad(a, ((0, pp), (0, 0)))
        b = jnp.pad(b, ((0, pp), (0, 0)))
        cutoff = jnp.pad(cutoff, (0, pp), constant_values=_INF)
    Pp = P + pp
    # host-side band packing: a2p[wb + t] = a[t//2], b2p[wb + t] = b[(2L-1-t)//2]
    a2 = jnp.repeat(a, 2, axis=-1)
    b2f = jnp.flip(jnp.repeat(b, 2, axis=-1), axis=-1)
    zl = jnp.zeros((Pp, wb), a.dtype)
    zr = jnp.zeros((Pp, pad_len - wb - 2 * L), a.dtype)
    a2p = jnp.concatenate([zl, a2, zr], axis=-1)         # (Pp, pad_len)
    b2p = jnp.concatenate([zl, b2f, zr], axis=-1)
    out = pl.pallas_call(
        functools.partial(_dtw_band_kernel, L=L, w=wb, Wb=Wb),
        grid=(Pp // tile_p,),
        in_specs=[
            pl.BlockSpec((tile_p, pad_len), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, pad_len), lambda i: (i, 0)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
        interpret=interpret,
    )(a2p, b2p, cutoff)
    return out[:P]
