"""Pallas TPU kernel: banded DTW, lane-parallel anti-diagonal wavefront.

This is the cascade's expensive verification step (paper Eq. 1-2 with the
Sakoe-Chiba window).  GPU DTW implementations put one *pair* per thread
block and wavefront within the matrix; the TPU-native layout is the
transpose (DESIGN.md SS3): a *batch of pairs* fills the vector lanes and the
DP sweeps the ``2L - 1`` anti-diagonals sequentially.  Every step is a
handful of full-width ``(TP, L)`` VPU ops; there is no data-dependent
control flow anywhere.

Key trick: on anti-diagonal ``d`` the candidate values needed are
``b[d - i]`` for all ``i`` — a *contiguous, reversed* slice of ``b``.  We
flip and zero-pad ``b`` once into a ``(TP, 3L)`` scratch so each step is a
single ``dynamic_slice`` (no gathers; Mosaic-friendly).

State: two diagonal buffers ``(TP, L)``; out-of-band / out-of-range cells
ride along as +inf.  VMEM: a, b (2 x TP*L) + flipped pad (TP*3L) + 2
diagonals (2 x TP*L) ~= 7*TP*L f32: TP=128, L=2048 -> 7.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array

_INF = float(jnp.inf)


def _dtw_band_kernel(a_ref, b_ref, out_ref, *, w: int):
    a = a_ref[...]                                       # (TP, L)
    b = b_ref[...]
    tp, L = a.shape
    dt = a.dtype
    # b_flip_pad[:, L + t] = b[:, L - 1 - t]
    zeros = jnp.zeros((tp, L), dt)
    b_flip = jnp.flip(b, axis=-1)
    bfp = jnp.concatenate([zeros, b_flip, zeros], axis=-1)  # (TP, 3L)
    ii = lax.broadcasted_iota(jnp.int32, (tp, L), 1)

    def step(d, carry):
        d1, d2 = carry                                   # diagonals d-1, d-2
        # b[d - i] = b_flip[L - 1 - d + i] -> slice of bfp at 2L - 1 - d
        b_at = lax.dynamic_slice(bfp, (0, 2 * L - 1 - d), (tp, L))
        diff = a - b_at
        cost = diff * diff
        inf_col = jnp.full((tp, 1), _INF, dt)
        up = d1                                          # D(i, j-1)
        left = jnp.concatenate([inf_col, d1[:, :-1]], axis=-1)   # D(i-1, j)
        diag = jnp.concatenate([inf_col, d2[:, :-1]], axis=-1)   # D(i-1, j-1)
        best = jnp.minimum(jnp.minimum(up, left), diag)
        jj = d - ii
        origin = (ii == 0) & (jj == 0)
        nd = cost + jnp.where(origin, 0.0, best)
        valid = (jj >= 0) & (jj < L) & (jnp.abs(ii - jj) <= w)
        nd = jnp.where(valid, nd, _INF)
        return nd, d1

    init = (jnp.full((tp, L), _INF, dt), jnp.full((tp, L), _INF, dt))
    dlast, _ = lax.fori_loop(0, 2 * L - 1, step, init)
    out_ref[...] = dlast[:, L - 1]


@functools.partial(
    jax.jit, static_argnames=("w", "tile_p", "interpret")
)
def dtw_band_pallas(
    a: Array,
    b: Array,
    w: int | None = None,
    *,
    tile_p: int = 128,
    interpret: bool = False,
) -> Array:
    """Pairwise banded DTW: ``(P, L), (P, L) -> (P,)`` squared-cost values."""
    P, L = a.shape
    if w is None or w >= L:
        w = L
    tile_p = min(tile_p, P)
    pp = (-P) % tile_p
    if pp:
        a = jnp.pad(a, ((0, pp), (0, 0)))
        b = jnp.pad(b, ((0, pp), (0, 0)))
    Pp = P + pp
    out = pl.pallas_call(
        functools.partial(_dtw_band_kernel, w=w),
        grid=(Pp // tile_p,),
        in_specs=[
            pl.BlockSpec((tile_p, L), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, L), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
        interpret=interpret,
    )(a, b)
    return out[:P]
