"""Pallas TPU kernel: banded DTW, band-packed wavefront with row-block
early exit.

This is the cascade's expensive verification step (paper Eq. 1-2 with the
Sakoe-Chiba window).  GPU DTW implementations put one *pair* per thread
block and wavefront within the matrix; the TPU-native layout is the
transpose (DESIGN.md SS3): a *batch of pairs* fills the sublanes and the DP
sweeps anti-diagonals sequentially with no data-dependent control flow.

Band-packed state (the O(L*W) layout): a DP cell is addressed by its
anti-diagonal ``d = i + j`` and diagonal offset ``k = i - j + w``; the state
per anti-diagonal is a dense ``(TP, Wb)`` block with ``Wb = 2w + 1`` rounded
up to the 128-lane multiple — *not* a ``(TP, L)`` full-width wavefront.
The recurrence is pure lane shifts:

    S_d[k] = cost(i, j) + min(S_{d-1}[k-1], S_{d-1}[k+1], S_{d-2}[k])

with ``i = (d + k - w)/2`` (cells exist only at matching parity).  The cost
operands are *contiguous* slices of the 2x-duplicated series
``A2[t] = a[t//2]`` and the flipped duplicate of ``b`` — both packed on the
host, so each anti-diagonal step is two ``dynamic_slice`` calls plus a
handful of ``(TP, Wb)`` VPU ops.

Row-block early exit (this file's grid): PR 1's kernel poisoned abandoned
lanes to +inf but still swept all ``2L - 1`` anti-diagonals per pair tile —
dead lanes *rode along*.  Herrmann & Webb (arXiv:2102.05221) show pruned
DTW wins come from skipping work blocks, so the grid here is
``(pair_tile, row_block)``: the anti-diagonals are grouped into
``row_block_policy(L)``-sized blocks, the DP frontier (two ``(TP, Wb)``
buffers) is *carried across grid steps in VMEM scratch*, and a scalar
liveness flag in SMEM steers each block:

  * block 0 resets the frontier and raises the flag;
  * every block runs its sweep under ``pl.when(live)`` — once the flag
    drops, remaining blocks return immediately (the whole anti-diagonal
    sweep is genuinely skipped, not masked);
  * at each block boundary the per-pair frontier minimum
    ``min(S_d, S_{d-1})`` — a valid DTW lower bound, since every warping
    path crosses anti-diagonal ``d`` or ``d-1`` and prefix costs only grow
    — is tested against the per-pair ``cutoff``; dead lanes are poisoned
    to +inf, and the flag drops when every lane in the tile is dead;
  * the last block writes the output (poisoned tiles emit +inf).

Because the frontier minimum is monotone non-decreasing in ``d``, the
block-boundary test abandons exactly the lanes the per-step test would —
outputs are identical, decisions just land on block boundaries.  The jnp
reference (core/dtw.py ``dtw_band_blocked``) shares both the per-step
recurrence (``core.dtw.band_step`` — one definition, used verbatim by the
kernel bodies below) and the block boundaries (``row_block_policy``),
keeping kernel and oracle bit-comparable by construction.
Moving the cross-lane frontier reduction out of the inner loop also
shrinks the per-step op count: the hot loop is now slices + shifts + adds
only.

``early_exit=False`` keeps PR 1's one-grid-step-per-pair-tile kernel with
per-step lane poisoning — the baseline the benchmark trajectory
(BENCH_kernels.json ``dtw_band_pr1_*`` rows) measures the early-exit grid
against.

VMEM budget (per grid step): packed operands a2p + b2p are
``2 * TP * pad_len`` f32 with ``pad_len ~= 2L + Wb``, plus 2 frontier
buffers (scratch for the blocked grid) and ~4 temporaries of ``TP * Wb`` —
``(4L + ~8Wb) * TP * 4`` bytes.  TP=128, L=2048, w=205 (0.1L, Wb=512):
~6.2 MB.  ``tile_p`` auto-shrinks (multiples of 8) to keep long series
inside ``_VMEM_BUDGET``, which is what lets ``_DTW_MAX_L`` in ops.py sit at
16384 (L=16384, small w -> TP=32, ~8.6 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dtw import band_step, row_block_policy
from repro.kernels.tiling import pick_pair_tile, round_up

Array = jax.Array

_INF = float(jnp.inf)
_VMEM_BUDGET = 10 * 2**20          # bytes for packed operands + DP state


def _dtw_band_kernel(a2p_ref, b2p_ref, cut_ref, out_ref, *, L: int, w: int,
                     Wb: int):
    """PR 1 baseline: one grid step per pair tile, per-step lane poisoning.

    Kept as the ``early_exit=False`` path so the benchmark trajectory can
    measure the row-block grid against it.
    """
    a2p = a2p_ref[...]                                   # (TP, pad_len)
    b2p = b2p_ref[...]
    cut = cut_ref[...][:, None]                          # (TP, 1)
    tp = a2p.shape[0]
    dt = a2p.dtype
    kk = lax.broadcasted_iota(jnp.int32, (tp, Wb), 1)

    def step(d, carry):
        nd, d1 = band_step(d, carry, a2p, b2p, kk, L=L, w=w)
        # every path crosses diagonal d or d-1 -> frontier min is a LB
        fmin = jnp.min(jnp.minimum(nd, d1), axis=-1, keepdims=True)
        dead = fmin > cut
        nd = jnp.where(dead, _INF, nd)
        d1 = jnp.where(dead, _INF, d1)
        return nd, d1

    init = (jnp.full((tp, Wb), _INF, dt), jnp.full((tp, Wb), _INF, dt))
    dlast, _ = lax.fori_loop(0, 2 * L - 1, step, init)
    out_ref[...] = dlast[:, w]


def _dtw_band_kernel_blocked(a2p_ref, b2p_ref, cut_ref, out_ref,
                             s1_ref, s2_ref, live_ref, *, L: int, w: int,
                             Wb: int, R: int):
    """Row-block grid step: sweep ``R`` anti-diagonals iff the tile lives.

    ``s1/s2`` carry the DP frontier across grid steps; ``live`` is the SMEM
    liveness flag that turns a fully-poisoned tile's remaining blocks into
    immediate returns.
    """
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)
    D = 2 * L - 1

    @pl.when(j == 0)
    def _reset():
        s1_ref[...] = jnp.full(s1_ref.shape, _INF, s1_ref.dtype)
        s2_ref[...] = jnp.full(s2_ref.shape, _INF, s2_ref.dtype)
        live_ref[0] = 1

    @pl.when(live_ref[0] == 1)
    def _sweep():
        a2p = a2p_ref[...]                               # (TP, pad_len)
        b2p = b2p_ref[...]
        cut = cut_ref[...][:, None]                      # (TP, 1)
        tp = a2p.shape[0]
        kk = lax.broadcasted_iota(jnp.int32, (tp, Wb), 1)
        d0 = j * R
        n_steps = jnp.minimum(R, D - d0)                 # last block is short

        def step(t, carry):
            return band_step(d0 + t, carry, a2p, b2p, kk, L=L, w=w)

        d1, d2 = lax.fori_loop(0, n_steps, step, (s1_ref[...], s2_ref[...]))
        # block-boundary abandon: min(S_d, S_{d-1}) lower-bounds final DTW
        fmin = jnp.min(jnp.minimum(d1, d2), axis=-1, keepdims=True)
        dead = fmin > cut
        s1_ref[...] = jnp.where(dead, _INF, d1)
        s2_ref[...] = jnp.where(dead, _INF, d2)
        live_ref[0] = jnp.any(jnp.logical_not(dead)).astype(jnp.int32)

    @pl.when(j == n_blocks - 1)
    def _emit():
        out_ref[...] = s1_ref[...][:, w]


@functools.partial(
    jax.jit,
    static_argnames=("w", "tile_p", "interpret", "early_exit", "row_block"),
)
def dtw_band_pallas(
    a: Array,
    b: Array,
    w: int | None = None,
    cutoff: Array | None = None,
    *,
    tile_p: int = 128,
    interpret: bool = False,
    early_exit: bool = True,
    row_block: int | None = None,
) -> Array:
    """Pairwise banded DTW: ``(P, L), (P, L) -> (P,)`` squared-cost values.

    ``cutoff`` is an optional per-pair ``(P,)`` early-abandon threshold:
    pairs whose true distance is strictly below their cutoff return the
    exact value; others return ``>= cutoff`` (normally +inf).

    ``early_exit`` selects the ``(pair_tile, row_block)`` grid whose
    fully-poisoned tiles skip their remaining anti-diagonal blocks;
    ``False`` runs PR 1's single-step grid with per-step lane poisoning
    (same results, no block skipping).  ``row_block`` overrides the
    ``row_block_policy(L)`` block size (testing/benchmarks).
    """
    P, L = a.shape
    if w is None or w >= L:
        w = L
    wb = min(w, L - 1)                 # |i - j| <= L - 1 always holds
    Wb = round_up(2 * wb + 1, 128)
    pad_len = round_up(2 * L + Wb + wb, 128)
    # auto-shrink the pair tile so packed operands + state fit VMEM
    per_row = (2 * pad_len + 8 * Wb) * 4
    tile_p = pick_pair_tile(tile_p, P, per_row, _VMEM_BUDGET)
    if cutoff is None:
        cutoff = jnp.full((P,), _INF, a.dtype)
    else:
        cutoff = jnp.broadcast_to(jnp.asarray(cutoff, a.dtype), (P,))
    pp = (-P) % tile_p
    if pp:
        a = jnp.pad(a, ((0, pp), (0, 0)))
        b = jnp.pad(b, ((0, pp), (0, 0)))
        # pad lanes get a -inf cutoff so they die at the first abandon
        # check — a +inf cutoff would keep them alive forever and pin the
        # liveness flag up, disabling early exit for the remainder tile
        cutoff = jnp.pad(cutoff, (0, pp), constant_values=-_INF)
    Pp = P + pp
    # host-side band packing: a2p[wb + t] = a[t//2], b2p[wb + t] = b[(2L-1-t)//2]
    a2 = jnp.repeat(a, 2, axis=-1)
    b2f = jnp.flip(jnp.repeat(b, 2, axis=-1), axis=-1)
    zl = jnp.zeros((Pp, wb), a.dtype)
    zr = jnp.zeros((Pp, pad_len - wb - 2 * L), a.dtype)
    a2p = jnp.concatenate([zl, a2, zr], axis=-1)         # (Pp, pad_len)
    b2p = jnp.concatenate([zl, b2f, zr], axis=-1)
    if not early_exit:
        out = pl.pallas_call(
            functools.partial(_dtw_band_kernel, L=L, w=wb, Wb=Wb),
            grid=(Pp // tile_p,),
            in_specs=[
                pl.BlockSpec((tile_p, pad_len), lambda i: (i, 0)),
                pl.BlockSpec((tile_p, pad_len), lambda i: (i, 0)),
                pl.BlockSpec((tile_p,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((tile_p,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
            interpret=interpret,
        )(a2p, b2p, cutoff)
        return out[:P]
    D = 2 * L - 1
    R = row_block if row_block is not None else row_block_policy(L)
    R = max(1, min(R, D))
    n_blocks = -(-D // R)
    out = pl.pallas_call(
        functools.partial(_dtw_band_kernel_blocked, L=L, w=wb, Wb=Wb, R=R),
        grid=(Pp // tile_p, n_blocks),
        in_specs=[
            pl.BlockSpec((tile_p, pad_len), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p, pad_len), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tile_p,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_p, Wb), a.dtype),
            pltpu.VMEM((tile_p, Wb), a.dtype),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(a2p, b2p, cutoff)
    return out[:P]
