"""Pallas TPU kernel: banded DTW, band-packed wavefront with row-block
early exit.

This is the cascade's expensive verification step (paper Eq. 1-2 with the
Sakoe-Chiba window).  GPU DTW implementations put one *pair* per thread
block and wavefront within the matrix; the TPU-native layout is the
transpose (DESIGN.md SS3): a *batch of pairs* fills the sublanes and the DP
sweeps anti-diagonals sequentially with no data-dependent control flow.

Band-packed state (the O(L*W) layout): a DP cell is addressed by its
anti-diagonal ``d = i + j`` and diagonal offset ``k = i - j + w``; the state
per anti-diagonal is a dense ``(TP, Wb)`` block with ``Wb = 2w + 1`` rounded
up to the 128-lane multiple — *not* a ``(TP, L)`` full-width wavefront.
The recurrence is pure lane shifts:

    S_d[k] = cost(i, j) + min(S_{d-1}[k-1], S_{d-1}[k+1], S_{d-2}[k])

with ``i = (d + k - w)/2`` (cells exist only at matching parity).  The cost
operands are *contiguous* slices of the 2x-duplicated series
``A2[t] = a[t//2]`` and the flipped duplicate of ``b`` — both packed on the
host, so each anti-diagonal step is two ``dynamic_slice`` calls plus a
handful of ``(TP, Wb)`` VPU ops.

Row-block early exit (this file's grid): PR 1's kernel poisoned abandoned
lanes to +inf but still swept all ``2L - 1`` anti-diagonals per pair tile —
dead lanes *rode along*.  Herrmann & Webb (arXiv:2102.05221) show pruned
DTW wins come from skipping work blocks, so the grid here is
``(pair_tile, row_block)``: the anti-diagonals are grouped into
``row_block_policy(L)``-sized blocks, the DP frontier (two ``(TP, Wb)``
buffers) is *carried across grid steps in VMEM scratch*, and a scalar
liveness flag in SMEM steers each block:

  * block 0 resets the frontier and raises the flag;
  * every block runs its sweep under ``pl.when(live)`` — once the flag
    drops, remaining blocks return immediately (the whole anti-diagonal
    sweep is genuinely skipped, not masked);
  * at each block boundary the per-pair frontier minimum
    ``min(S_d, S_{d-1})`` — a valid DTW lower bound, since every warping
    path crosses anti-diagonal ``d`` or ``d-1`` and prefix costs only grow
    — is tested against the per-pair ``cutoff``; dead lanes are poisoned
    to +inf, and the flag drops when every lane in the tile is dead;
  * the last block writes the output (poisoned tiles emit +inf).

Because the frontier minimum is monotone non-decreasing in ``d``, the
block-boundary test abandons exactly the lanes the per-step test would —
outputs are identical, decisions just land on block boundaries.  The jnp
reference (core/dtw.py ``dtw_band_blocked``) shares both the per-step
recurrence (``core.dtw.band_step`` — one definition, used verbatim by the
kernel bodies below) and the block boundaries (``row_block_policy``),
keeping kernel and oracle bit-comparable by construction.
Moving the cross-lane frontier reduction out of the inner loop also
shrinks the per-step op count: the hot loop is now slices + shifts + adds
only.

``early_exit=False`` keeps PR 1's one-grid-step-per-pair-tile kernel with
per-step lane poisoning — the baseline the benchmark trajectory
(BENCH_kernels.json ``dtw_band_pr1_*`` rows) measures the early-exit grid
against.

Streaming grid (``stream=True``): the resident grid above keeps the whole
packed operands ``a2p``/``b2p`` (``~2 * TP * (2L + Wb)`` f32) in VMEM for
the entire sweep, which is what used to cap ``dtw_band_op`` at
``_DTW_MAX_L = 16384``.  The streaming kernel removes the length ceiling
by leaving the operands in HBM (``pltpu.ANY`` memory space) and turning
the row-block grid into a true DMA pipeline: row block ``j`` only ever
touches the operand windows ``a2p[:, jR : jR + R + Wb)`` and
``b2p[:, 2L - min(D, (j+1)R) : ... + R + Wb)``, so each ``(pair_tile,
row_block)`` step double-buffers those windows — block ``j + 1``'s async
copies are issued *before* block ``j``'s sweep and waited at the top of
step ``j + 1``, overlapping DMA with compute everywhere except the
warm-up block.  The DP frontier is carried in VMEM scratch exactly as in
the resident grid, and the sweep runs the same ``band_step`` recurrence
(with the window origins passed as ``a_off``/``b_off``), so streaming,
resident, and the jnp ``dtw_band_blocked`` reference stay bit-comparable
by construction.  Two SMEM flags steer the pipeline: ``live`` (as in the
resident grid) and ``pending`` (a DMA pair is in flight for the current
block).  A fully-poisoned tile stops *issuing* DMAs as well as computing:
the step that kills the tile has already issued block ``j + 1``'s copies,
so the next step drains them (keeping semaphores balanced) and every
block after that is a pure no-op until the final block emits the +inf
outputs.

DMA-pipeline budget (per grid step — this is the whole point: the
working set no longer contains ``L``): 2 double-buffer slots x 2 operand
windows of ``Wwin = R + Wb`` lanes, plus the 2-buffer frontier and ~4
``band_step`` temporaries of ``Wb`` lanes — ``(4 Wwin + ~8 Wb) * TP * 4``
bytes, independent of series length.  ``tiling.stream_geometry`` picks
the largest ``(tile_p, R)`` that fits ``_VMEM_BUDGET`` (preferring the
shared ``row_block_policy`` block so abandon boundaries match the
reference, halving ``R`` in 64-step multiples when the window is too
wide); only when the band state itself (``~8 Wb`` lanes at the 8-sublane
floor) exceeds the budget — e.g. ``w = L`` at ``L = 64k`` — does ops.py
fall back to the jnp reference.  L=65536, w=0.01L (Wb=1408): policy picks
TP=24, R=16384 — ~7.9 MB, where the resident layout would need ~550 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dtw import band_step, row_block_policy
from repro.kernels.tiling import (
    Wb_pad,
    pick_pair_tile,
    round_up,
    stream_geometry,
)

Array = jax.Array

_INF = float(jnp.inf)
_VMEM_BUDGET = 10 * 2**20          # bytes for packed operands + DP state


def _dtw_band_kernel(a2p_ref, b2p_ref, cut_ref, out_ref, *, L: int, w: int,
                     Wb: int):
    """PR 1 baseline: one grid step per pair tile, per-step lane poisoning.

    Kept as the ``early_exit=False`` path so the benchmark trajectory can
    measure the row-block grid against it.
    """
    a2p = a2p_ref[...]                                   # (TP, pad_len)
    b2p = b2p_ref[...]
    cut = cut_ref[...][:, None]                          # (TP, 1)
    tp = a2p.shape[0]
    dt = a2p.dtype
    kk = lax.broadcasted_iota(jnp.int32, (tp, Wb), 1)

    def step(d, carry):
        nd, d1 = band_step(d, carry, a2p, b2p, kk, L=L, w=w)
        # every path crosses diagonal d or d-1 -> frontier min is a LB
        fmin = jnp.min(jnp.minimum(nd, d1), axis=-1, keepdims=True)
        dead = fmin > cut
        nd = jnp.where(dead, _INF, nd)
        d1 = jnp.where(dead, _INF, d1)
        return nd, d1

    init = (jnp.full((tp, Wb), _INF, dt), jnp.full((tp, Wb), _INF, dt))
    dlast, _ = lax.fori_loop(0, 2 * L - 1, step, init)
    out_ref[...] = dlast[:, w]


def _dtw_band_kernel_blocked(a2p_ref, b2p_ref, cut_ref, out_ref,
                             s1_ref, s2_ref, live_ref, *, L: int, w: int,
                             Wb: int, R: int):
    """Row-block grid step: sweep ``R`` anti-diagonals iff the tile lives.

    ``s1/s2`` carry the DP frontier across grid steps; ``live`` is the SMEM
    liveness flag that turns a fully-poisoned tile's remaining blocks into
    immediate returns.
    """
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)
    D = 2 * L - 1

    @pl.when(j == 0)
    def _reset():
        s1_ref[...] = jnp.full(s1_ref.shape, _INF, s1_ref.dtype)
        s2_ref[...] = jnp.full(s2_ref.shape, _INF, s2_ref.dtype)
        live_ref[0] = 1

    @pl.when(live_ref[0] == 1)
    def _sweep():
        a2p = a2p_ref[...]                               # (TP, pad_len)
        b2p = b2p_ref[...]
        cut = cut_ref[...][:, None]                      # (TP, 1)
        tp = a2p.shape[0]
        kk = lax.broadcasted_iota(jnp.int32, (tp, Wb), 1)
        d0 = j * R
        n_steps = jnp.minimum(R, D - d0)                 # last block is short

        def step(t, carry):
            return band_step(d0 + t, carry, a2p, b2p, kk, L=L, w=w)

        d1, d2 = lax.fori_loop(0, n_steps, step, (s1_ref[...], s2_ref[...]))
        # block-boundary abandon: min(S_d, S_{d-1}) lower-bounds final DTW
        fmin = jnp.min(jnp.minimum(d1, d2), axis=-1, keepdims=True)
        dead = fmin > cut
        s1_ref[...] = jnp.where(dead, _INF, d1)
        s2_ref[...] = jnp.where(dead, _INF, d2)
        live_ref[0] = jnp.any(jnp.logical_not(dead)).astype(jnp.int32)

    @pl.when(j == n_blocks - 1)
    def _emit():
        out_ref[...] = s1_ref[...][:, w]


def _dtw_band_kernel_stream(a2p_ref, b2p_ref, cut_ref, out_ref,
                            abuf, bbuf, s1_ref, s2_ref, flags_ref,
                            asem, bsem, *, L: int, w: int, Wb: int, R: int,
                            Wwin: int, TP: int):
    """Streaming row-block grid step: HBM-resident operands, double-
    buffered per-block windows, DMA overlapped with the previous block's
    sweep.

    ``flags_ref[0]`` is the liveness flag (as in the resident grid);
    ``flags_ref[1]`` records that a DMA pair for the *current* block is
    in flight, so the one copy issued before the tile died still gets
    drained and the semaphores stay balanced.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    n_blocks = pl.num_programs(1)
    D = 2 * L - 1

    def window_dmas(blk, slot):
        # block `blk` sweeps d in [blk*R, min(D, (blk+1)*R)): band_step
        # slices a2p at d and b2p at 2L-1-d, so its operand windows are
        # Wwin = R + Wb lanes starting at these offsets
        aoff = blk * R
        boff = 2 * L - jnp.minimum(D, (blk + 1) * R)
        rows = pl.ds(i * TP, TP)
        da = pltpu.make_async_copy(
            a2p_ref.at[rows, pl.ds(aoff, Wwin)], abuf.at[slot],
            asem.at[slot])
        db = pltpu.make_async_copy(
            b2p_ref.at[rows, pl.ds(boff, Wwin)], bbuf.at[slot],
            bsem.at[slot])
        return da, db

    @pl.when(j == 0)
    def _reset():
        s1_ref[...] = jnp.full(s1_ref.shape, _INF, s1_ref.dtype)
        s2_ref[...] = jnp.full(s2_ref.shape, _INF, s2_ref.dtype)
        flags_ref[0] = 1
        da, db = window_dmas(0, 0)        # warm-up block: no overlap
        da.start()
        db.start()
        flags_ref[1] = 1

    @pl.when(flags_ref[1] == 1)
    def _arrive():
        # wait for the current block's windows (issued at step j-1, or by
        # the warm-up above); runs even when the tile is already dead so
        # the last issued copy is always drained exactly once
        slot = lax.rem(j, 2)
        da, db = window_dmas(j, slot)
        da.wait()
        db.wait()
        flags_ref[1] = 0

    @pl.when(flags_ref[0] == 1)
    def _sweep():
        slot = lax.rem(j, 2)

        @pl.when(j + 1 < n_blocks)
        def _prefetch():
            # issue block j+1's windows before this block's sweep so the
            # copies fly while we compute; dead tiles never reach here,
            # which is what turns the liveness exit into skipped DMA too
            da, db = window_dmas(j + 1, lax.rem(j + 1, 2))
            da.start()
            db.start()
            flags_ref[1] = 1

        a2w = abuf[slot]                                 # (TP, Wwin)
        b2w = bbuf[slot]
        cut = cut_ref[...][:, None]                      # (TP, 1)
        kk = lax.broadcasted_iota(jnp.int32, (TP, Wb), 1)
        d0 = j * R
        boff = 2 * L - jnp.minimum(D, (j + 1) * R)
        n_steps = jnp.minimum(R, D - d0)                 # last block is short

        def step(t, carry):
            return band_step(d0 + t, carry, a2w, b2w, kk, L=L, w=w,
                             a_off=d0, b_off=boff)

        d1, d2 = lax.fori_loop(0, n_steps, step, (s1_ref[...], s2_ref[...]))
        # block-boundary abandon: min(S_d, S_{d-1}) lower-bounds final DTW
        fmin = jnp.min(jnp.minimum(d1, d2), axis=-1, keepdims=True)
        dead = fmin > cut
        s1_ref[...] = jnp.where(dead, _INF, d1)
        s2_ref[...] = jnp.where(dead, _INF, d2)
        flags_ref[0] = jnp.any(jnp.logical_not(dead)).astype(jnp.int32)

    @pl.when(j == n_blocks - 1)
    def _emit():
        out_ref[...] = s1_ref[...][:, w]


def _pack_band_operands(a: Array, b: Array, cutoff: Array | None, wb: int,
                        pad_len: int, tile_p: int):
    """Host-side band packing shared by the resident and streaming paths
    (one definition — the two grids' bit-equality depends on identical
    operand layout): pad the pair axis to the tile, build the
    2x-duplicated shifted operands ``a2p[wb + t] = a[t//2]`` /
    ``b2p[wb + t] = b[(2L-1-t)//2]``.  Pad lanes get a -inf cutoff so
    they die at the first abandon check — a +inf cutoff would keep them
    alive forever and pin the liveness flag up, disabling early exit for
    the remainder tile.  Returns ``(a2p, b2p, cutoff, Pp)``.
    """
    P, L = a.shape
    if cutoff is None:
        cutoff = jnp.full((P,), _INF, a.dtype)
    else:
        cutoff = jnp.broadcast_to(jnp.asarray(cutoff, a.dtype), (P,))
    pp = (-P) % tile_p
    if pp:
        a = jnp.pad(a, ((0, pp), (0, 0)))
        b = jnp.pad(b, ((0, pp), (0, 0)))
        cutoff = jnp.pad(cutoff, (0, pp), constant_values=-_INF)
    Pp = P + pp
    a2 = jnp.repeat(a, 2, axis=-1)
    b2f = jnp.flip(jnp.repeat(b, 2, axis=-1), axis=-1)
    zl = jnp.zeros((Pp, wb), a.dtype)
    zr = jnp.zeros((Pp, pad_len - wb - 2 * L), a.dtype)
    a2p = jnp.concatenate([zl, a2, zr], axis=-1)         # (Pp, pad_len)
    b2p = jnp.concatenate([zl, b2f, zr], axis=-1)
    return a2p, b2p, cutoff, Pp


@functools.partial(
    jax.jit,
    static_argnames=("w", "tile_p", "interpret", "early_exit", "row_block",
                     "stream"),
)
def dtw_band_pallas(
    a: Array,
    b: Array,
    w: int | None = None,
    cutoff: Array | None = None,
    *,
    tile_p: int = 128,
    interpret: bool = False,
    early_exit: bool = True,
    row_block: int | None = None,
    stream: bool = False,
) -> Array:
    """Pairwise banded DTW: ``(P, L), (P, L) -> (P,)`` squared-cost values.

    ``cutoff`` is an optional per-pair ``(P,)`` early-abandon threshold:
    pairs whose true distance is strictly below their cutoff return the
    exact value; others return ``>= cutoff`` (normally +inf).

    ``early_exit`` selects the ``(pair_tile, row_block)`` grid whose
    fully-poisoned tiles skip their remaining anti-diagonal blocks;
    ``False`` runs PR 1's single-step grid with per-step lane poisoning
    (same results, no block skipping).  ``row_block`` overrides the
    ``row_block_policy(L)`` block size (testing/benchmarks).

    ``stream`` runs the DMA-pipelined grid instead: operands stay in HBM
    and each row block double-buffers its operand windows (module
    docstring), so VMEM holds only the per-block working set and there is
    no length ceiling.  Implies the early-exit liveness behaviour; the
    caller (ops.dtw_band_op) picks this path automatically for series
    beyond the resident budget.  Raises ``ValueError`` when even the
    minimum streaming block cannot fit VMEM (band state wider than the
    budget) — ops.py routes those shapes to the jnp reference instead.
    """
    P, L = a.shape
    if w is None or w >= L:
        w = L
    wb = min(w, L - 1)                 # |i - j| <= L - 1 always holds
    Wb = Wb_pad(wb)
    if stream:
        return _dtw_band_pallas_stream(
            a, b, wb, cutoff, tile_p=tile_p, interpret=interpret,
            row_block=row_block,
        )
    pad_len = round_up(2 * L + Wb + wb, 128)
    # auto-shrink the pair tile so packed operands + state fit VMEM
    per_row = (2 * pad_len + 8 * Wb) * 4
    tile_p = pick_pair_tile(tile_p, P, per_row, _VMEM_BUDGET)
    a2p, b2p, cutoff, Pp = _pack_band_operands(a, b, cutoff, wb, pad_len,
                                               tile_p)
    if not early_exit:
        out = pl.pallas_call(
            functools.partial(_dtw_band_kernel, L=L, w=wb, Wb=Wb),
            grid=(Pp // tile_p,),
            in_specs=[
                pl.BlockSpec((tile_p, pad_len), lambda i: (i, 0)),
                pl.BlockSpec((tile_p, pad_len), lambda i: (i, 0)),
                pl.BlockSpec((tile_p,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((tile_p,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
            interpret=interpret,
        )(a2p, b2p, cutoff)
        return out[:P]
    D = 2 * L - 1
    R = row_block if row_block is not None else row_block_policy(L)
    R = max(1, min(R, D))
    n_blocks = -(-D // R)
    out = pl.pallas_call(
        functools.partial(_dtw_band_kernel_blocked, L=L, w=wb, Wb=Wb, R=R),
        grid=(Pp // tile_p, n_blocks),
        in_specs=[
            pl.BlockSpec((tile_p, pad_len), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p, pad_len), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tile_p,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_p, Wb), a.dtype),
            pltpu.VMEM((tile_p, Wb), a.dtype),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(a2p, b2p, cutoff)
    return out[:P]


def _dtw_band_pallas_stream(
    a: Array,
    b: Array,
    wb: int,
    cutoff: Array | None,
    *,
    tile_p: int,
    interpret: bool,
    row_block: int | None,
) -> Array:
    """Streaming path of ``dtw_band_pallas`` (already inside its jit)."""
    P, L = a.shape
    Wb = Wb_pad(wb)
    D = 2 * L - 1
    geom = stream_geometry(L, wb, tile_p, P, _VMEM_BUDGET,
                           row_block=row_block)
    if geom is None:
        raise ValueError(
            f"streaming dtw_band: band state (~8 x {Wb} lanes) exceeds the "
            f"VMEM budget at the sublane floor (L={L}, w={wb}); use the "
            "jnp reference for this shape (ops.dtw_band_op does)"
        )
    tile_p, R = geom
    n_blocks = -(-D // R)
    Wwin = round_up(R + Wb, 128)
    # the host packing must cover every block window: block j reads
    # a2p[:, jR : jR + Wwin) and b2p[:, 2L - min(D, (j+1)R) : ... + Wwin)
    pad_len = round_up(
        max(2 * L + Wb + wb, (n_blocks - 1) * R + Wwin,
            2 * L - min(D, R) + Wwin),
        128,
    )
    a2p, b2p, cutoff, Pp = _pack_band_operands(a, b, cutoff, wb, pad_len,
                                               tile_p)
    out = pl.pallas_call(
        functools.partial(_dtw_band_kernel_stream, L=L, w=wb, Wb=Wb, R=R,
                          Wwin=Wwin, TP=tile_p),
        grid=(Pp // tile_p, n_blocks),
        in_specs=[
            # operands stay in HBM; the kernel DMAs its own windows
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((tile_p,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tile_p,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, tile_p, Wwin), a.dtype),      # A2 window slots
            pltpu.VMEM((2, tile_p, Wwin), a.dtype),      # B2 window slots
            pltpu.VMEM((tile_p, Wb), a.dtype),           # frontier S_{d-1}
            pltpu.VMEM((tile_p, Wb), a.dtype),           # frontier S_{d-2}
            pltpu.SMEM((2,), jnp.int32),                 # live, pending
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(a2p, b2p, cutoff)
    return out[:P]
