"""Pallas TPU kernel: fused LB_ENHANCED^V *cross blocks* (paper Eq. 14 /
Alg. 1).

The paper's contribution as a single fused kernel: for a ``(TQ, L)`` query
tile against a ``(TC, L)`` candidate tile (plus the candidates' envelopes),
each program emits the ``(TQ, TC)`` block of LB_ENHANCED^V bounds — elastic
left/right band minima *and* the Keogh bridge in one VMEM round trip.

Two kernel shapes serve LB_ENHANCED (see search/cascade.py DESIGN notes):

  * **cross-block** (this file): ``(TQ, L) x (TC, L) -> (TQ, TC)`` — every
    query row meets every candidate row.  The cascade uses it for the
    all-pairs tiers (dense tier 2 and the bands-only tier 1 prefilter),
    where the full (Q, N) bound matrix is the product.
  * **pairwise** (lb_enhanced_pairwise.py): packed ``(P, L)`` batches in,
    ``(P,)`` bounds out — row ``p`` of the query batch pairs with row
    ``p`` of the candidate batch.  The staged cascade's tier-2 refinement
    runs on *gather-compacted survivor pairs*, which is exactly this
    diagonal shape; the cross-block kernel would pay ``TQ x TC`` work for
    ``min(TQ, TC)`` answers there.

Band structure (SS III): band ``i < nb`` is L-shaped with arm width
``i + 1 <= nb`` — because ``nb = min(L/2, W, V)`` is a small compile-time
constant, the two band arms unroll into ``O(nb^2)`` static-slice vector ops
over the ``(TC,)`` lane axis: no gathers, no data-dependent control flow.
The paper's per-pair early abandon (Alg. 1 line 12) is deliberately absent:
on TPU it becomes cascade-tier compaction (see search/cascade.py), and the
bands-only tier is exposed separately via ``bands_only=True``.

Per-candidate liveness (``live``): liveness parity with the pairwise
kernel (PR 4) for the *dense* tier — the planner (search/planner.py) can
limit-mask a cross-block tier the same way it limit-masks the packed
tiers.  ``live`` is a ``(C,)`` per-candidate mask: dead candidates emit
``-inf`` down their whole output column (the running-max identity, so a
masked dense tier folds into the cascade as a no-op on dead candidates),
and a candidate tile whose lanes are *all* dead skips the band/bridge
compute entirely via the same SMEM-flag ``pl.when`` mechanism the
pairwise and DTW tiles use.

VMEM: q (TQ, L) + c/u/lo (3*TC, L) + out (TQ, TC).
TQ=8, TC=128, L=4096 -> ~6.4 MB f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

_INF = float(jnp.inf)


def _block_rows(q_ref, c_ref, u_ref, l_ref, out_ref, *, nb: int,
                bands_only: bool, live=None):
    """Write the (TQ, TC) bound block row by row (shared by the live-gated
    and ungated kernel bodies); ``live`` masks dead candidate lanes to
    ``-inf``."""
    c = c_ref[...]            # (TC, L)
    tq = q_ref.shape[0]
    L = q_ref.shape[1]

    if not bands_only:
        u = u_ref[...]
        lo = l_ref[...]

    def row(i, _):
        qrow = q_ref[i, :]                              # (L,)
        acc = jnp.zeros((c.shape[0],), dtype=out_ref.dtype)
        # --- elastic bands: unrolled static slices (nb is tiny) ---
        for bi in range(nb):
            # left band bi: cells (a_j, b_bi) and (a_bi, b_k), j,k in [0, bi]
            m = jnp.full((c.shape[0],), jnp.inf, dtype=acc.dtype)
            for t in range(bi + 1):
                d1 = qrow[bi - t] - c[:, bi]            # delta(a_{bi-t}, b_bi)
                d2 = qrow[bi] - c[:, bi - t]            # delta(a_bi, b_{bi-t})
                m = jnp.minimum(m, jnp.minimum(d1 * d1, d2 * d2))
            acc = acc + m
            # right band (mirror around L-1)
            ir = L - 1 - bi
            m = jnp.full((c.shape[0],), jnp.inf, dtype=acc.dtype)
            for t in range(bi + 1):
                d1 = qrow[ir + t] - c[:, ir]
                d2 = qrow[ir] - c[:, ir + t]
                m = jnp.minimum(m, jnp.minimum(d1 * d1, d2 * d2))
            acc = acc + m
        # --- Keogh bridge over [nb, L - nb) ---
        if not bands_only:
            qb = qrow[None, nb : L - nb]
            over = jnp.maximum(qb - u[:, nb : L - nb], 0.0)
            under = jnp.maximum(lo[:, nb : L - nb] - qb, 0.0)
            acc = acc + jnp.sum(over * over + under * under, axis=-1)
        out_ref[i, :] = acc if live is None else jnp.where(live, acc, -_INF)
        return 0

    lax.fori_loop(0, tq, row, 0, unroll=True)


def _lb_enhanced_kernel(
    q_ref, c_ref, u_ref, l_ref, out_ref, *, nb: int, bands_only: bool
):
    _block_rows(q_ref, c_ref, u_ref, l_ref, out_ref, nb=nb,
                bands_only=bands_only)


def _lb_enhanced_kernel_live(
    q_ref, c_ref, u_ref, l_ref, live_ref, out_ref, flag_ref, *, nb: int,
    bands_only: bool
):
    """Live-gated candidate tile: dead candidates emit -inf columns,
    all-dead tiles skip the band/bridge compute entirely (SMEM flag +
    ``pl.when`` — the pairwise/DTW tiles' liveness mechanism)."""
    live = live_ref[...] != 0                           # (TC,)
    flag_ref[0] = jnp.any(live).astype(jnp.int32)
    out_ref[...] = jnp.full(out_ref.shape, -_INF, out_ref.dtype)

    @pl.when(flag_ref[0] == 1)
    def _compute():
        _block_rows(q_ref, c_ref, u_ref, l_ref, out_ref, nb=nb,
                    bands_only=bands_only, live=live)


@functools.partial(
    jax.jit,
    static_argnames=("w", "v", "bands_only", "tile_q", "tile_c", "interpret"),
)
def lb_enhanced_pallas(
    q: Array,
    c: Array,
    u: Array,
    lo: Array,
    w: int,
    v: int,
    *,
    live: Array | None = None,
    bands_only: bool = False,
    tile_q: int = 8,
    tile_c: int = 128,
    interpret: bool = False,
) -> Array:
    """``(Q, L) x (C, L) -> (Q, C)`` fused LB_ENHANCED^V matrix.

    ``live`` (optional ``(C,)`` bool/int) marks which candidates are worth
    scoring: dead candidates return ``-inf`` for every query and
    fully-dead candidate tiles skip their compute (module docstring).
    ``None`` scores every candidate.
    """
    Q, L = q.shape
    C, _ = c.shape
    nb = max(0, min(L // 2, w, v))
    tile_q = min(tile_q, Q)
    tile_c = min(tile_c, C)
    if live is not None:
        live = jnp.broadcast_to(jnp.asarray(live), (C,)).astype(jnp.int32)
    pq, pc = (-Q) % tile_q, (-C) % tile_c
    if pq:
        q = jnp.pad(q, ((0, pq), (0, 0)))
    if pc:
        c = jnp.pad(c, ((0, pc), (0, 0)))
        u = jnp.pad(u, ((0, pc), (0, 0)), constant_values=jnp.inf)
        lo = jnp.pad(lo, ((0, pc), (0, 0)), constant_values=-jnp.inf)
        if live is not None:
            # pad candidates are dead, so they never hold a tile's flag up
            live = jnp.pad(live, (0, pc))
    Qp, Cp = Q + pq, C + pc
    grid = (Qp // tile_q, Cp // tile_c)
    out_shape = jax.ShapeDtypeStruct((Qp, Cp), q.dtype)
    in_specs = [
        pl.BlockSpec((tile_q, L), lambda i, j: (i, 0)),
        pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
        pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
        pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
    ]
    out_specs = pl.BlockSpec((tile_q, tile_c), lambda i, j: (i, j))
    if live is None:
        out = pl.pallas_call(
            functools.partial(_lb_enhanced_kernel, nb=nb,
                              bands_only=bands_only),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(q, c, u, lo)
    else:
        out = pl.pallas_call(
            functools.partial(_lb_enhanced_kernel_live, nb=nb,
                              bands_only=bands_only),
            grid=grid,
            in_specs=in_specs + [pl.BlockSpec((tile_c,), lambda i, j: (j,))],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
            interpret=interpret,
        )(q, c, u, lo, live)
    return out[:Q, :C]
