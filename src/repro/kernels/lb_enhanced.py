"""Pallas TPU kernel: fused LB_ENHANCED^V *cross blocks* (paper Eq. 14 /
Alg. 1).

The paper's contribution as a single fused kernel: for a ``(TQ, L)`` query
tile against a ``(TC, L)`` candidate tile (plus the candidates' envelopes),
each program emits the ``(TQ, TC)`` block of LB_ENHANCED^V bounds — elastic
left/right band minima *and* the Keogh bridge in one VMEM round trip.

Two kernel shapes serve LB_ENHANCED (see search/cascade.py DESIGN notes):

  * **cross-block** (this file): ``(TQ, L) x (TC, L) -> (TQ, TC)`` — every
    query row meets every candidate row.  The cascade uses it for the
    all-pairs tiers (dense tier 2 and the bands-only tier 1 prefilter),
    where the full (Q, N) bound matrix is the product.
  * **pairwise** (lb_enhanced_pairwise.py): packed ``(P, L)`` batches in,
    ``(P,)`` bounds out — row ``p`` of the query batch pairs with row
    ``p`` of the candidate batch.  The staged cascade's tier-2 refinement
    runs on *gather-compacted survivor pairs*, which is exactly this
    diagonal shape; the cross-block kernel would pay ``TQ x TC`` work for
    ``min(TQ, TC)`` answers there.

Band structure (SS III): band ``i < nb`` is L-shaped with arm width
``i + 1 <= nb`` — because ``nb = min(L/2, W, V)`` is a small compile-time
constant, the two band arms unroll into ``O(nb^2)`` static-slice vector ops
over the ``(TC,)`` lane axis: no gathers, no data-dependent control flow.
The paper's per-pair early abandon (Alg. 1 line 12) is deliberately absent:
on TPU it becomes cascade-tier compaction (see search/cascade.py), and the
bands-only tier is exposed separately via ``bands_only=True``.

VMEM: q (TQ, L) + c/u/lo (3*TC, L) + out (TQ, TC).
TQ=8, TC=128, L=4096 -> ~6.4 MB f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array


def _lb_enhanced_kernel(
    q_ref, c_ref, u_ref, l_ref, out_ref, *, nb: int, bands_only: bool
):
    c = c_ref[...]            # (TC, L)
    tq = q_ref.shape[0]
    L = q_ref.shape[1]

    if not bands_only:
        u = u_ref[...]
        lo = l_ref[...]

    def row(i, _):
        qrow = q_ref[i, :]                              # (L,)
        acc = jnp.zeros((c.shape[0],), dtype=out_ref.dtype)
        # --- elastic bands: unrolled static slices (nb is tiny) ---
        for bi in range(nb):
            # left band bi: cells (a_j, b_bi) and (a_bi, b_k), j,k in [0, bi]
            m = jnp.full((c.shape[0],), jnp.inf, dtype=acc.dtype)
            for t in range(bi + 1):
                d1 = qrow[bi - t] - c[:, bi]            # delta(a_{bi-t}, b_bi)
                d2 = qrow[bi] - c[:, bi - t]            # delta(a_bi, b_{bi-t})
                m = jnp.minimum(m, jnp.minimum(d1 * d1, d2 * d2))
            acc = acc + m
            # right band (mirror around L-1)
            ir = L - 1 - bi
            m = jnp.full((c.shape[0],), jnp.inf, dtype=acc.dtype)
            for t in range(bi + 1):
                d1 = qrow[ir + t] - c[:, ir]
                d2 = qrow[ir] - c[:, ir + t]
                m = jnp.minimum(m, jnp.minimum(d1 * d1, d2 * d2))
            acc = acc + m
        # --- Keogh bridge over [nb, L - nb) ---
        if not bands_only:
            qb = qrow[None, nb : L - nb]
            over = jnp.maximum(qb - u[:, nb : L - nb], 0.0)
            under = jnp.maximum(lo[:, nb : L - nb] - qb, 0.0)
            acc = acc + jnp.sum(over * over + under * under, axis=-1)
        out_ref[i, :] = acc
        return 0

    lax.fori_loop(0, tq, row, 0, unroll=True)


@functools.partial(
    jax.jit,
    static_argnames=("w", "v", "bands_only", "tile_q", "tile_c", "interpret"),
)
def lb_enhanced_pallas(
    q: Array,
    c: Array,
    u: Array,
    lo: Array,
    w: int,
    v: int,
    *,
    bands_only: bool = False,
    tile_q: int = 8,
    tile_c: int = 128,
    interpret: bool = False,
) -> Array:
    """``(Q, L) x (C, L) -> (Q, C)`` fused LB_ENHANCED^V matrix."""
    Q, L = q.shape
    C, _ = c.shape
    nb = max(0, min(L // 2, w, v))
    tile_q = min(tile_q, Q)
    tile_c = min(tile_c, C)
    pq, pc = (-Q) % tile_q, (-C) % tile_c
    if pq:
        q = jnp.pad(q, ((0, pq), (0, 0)))
    if pc:
        c = jnp.pad(c, ((0, pc), (0, 0)))
        u = jnp.pad(u, ((0, pc), (0, 0)), constant_values=jnp.inf)
        lo = jnp.pad(lo, ((0, pc), (0, 0)), constant_values=-jnp.inf)
    Qp, Cp = Q + pq, C + pc
    out = pl.pallas_call(
        functools.partial(_lb_enhanced_kernel, nb=nb, bands_only=bands_only),
        grid=(Qp // tile_q, Cp // tile_c),
        in_specs=[
            pl.BlockSpec((tile_q, L), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Cp), q.dtype),
        interpret=interpret,
    )(q, c, u, lo)
    return out[:Q, :C]
