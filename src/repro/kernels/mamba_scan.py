"""Pallas TPU kernel: fused Mamba-1 selective scan.

The XLA lowering of the chunked selective scan materialises every
associative-scan level as an HBM round trip — the dominant roofline term
for the SSM archs (EXPERIMENTS.md SSRoofline).  This kernel is the TPU
analogue of Mamba's "hardware-aware" CUDA scan: the recurrence state
``h (C_tile, N)`` lives in a VMEM scratch for the whole sequence, so HBM
traffic collapses to exactly the kernel's inputs and outputs:

    bytes = B*S*(2C + 2N)*in_bytes + B*S*C*out_bytes   (+ tiny h0/hT)

vs O(log(chunk) * B*S*C*N) for the XLA scan — a ~60x reduction at
falcon-mamba shapes.

Layout: grid (B, C/TC, S/TS); the sequence axis is the innermost
(sequential) grid dimension, carrying ``h`` across steps in scratch — the
standard Pallas accumulator idiom.  Channels fill the lanes; the in-chunk
time loop is sequential (true data dependence) over dense (TC, N) vector
ops.

Forward-only kernel: training wraps it in ``jax.custom_vjp`` whose
backward recomputes forward chunks (same recompute policy the chunked-scan
path uses); serving/prefill uses it directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _mamba_scan_kernel(
    d_ref,      # (1, TS, TC) delta (post-softplus) f32
    u_ref,      # (1, TS, TC)
    A_ref,      # (TC, N)
    b_ref,      # (1, TS, N)
    c_ref,      # (1, TS, N)
    h0_ref,     # (1, TC, N)
    y_ref,      # (1, TS, TC) out
    hT_ref,     # (1, TC, N) out (final state)
    h_scratch,  # (TC, N) VMEM
    *,
    ts: int,
    n_steps: int,
):
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        h_scratch[...] = h0_ref[0]

    A = A_ref[...]                                    # (TC, N)
    h = h_scratch[...]
    d = d_ref[0]                                      # (TS, TC)
    u = u_ref[0]
    bm = b_ref[0]                                     # (TS, N)
    cm = c_ref[0]

    def t_step(t, carry):
        h = carry
        dt = lax.dynamic_slice(d, (t, 0), (1, d.shape[1]))[0]     # (TC,)
        ut = lax.dynamic_slice(u, (t, 0), (1, u.shape[1]))[0]
        bt = lax.dynamic_slice(bm, (t, 0), (1, bm.shape[1]))[0]   # (N,)
        ct = lax.dynamic_slice(cm, (t, 0), (1, cm.shape[1]))[0]
        a_t = jnp.exp(dt[:, None] * A)                            # (TC, N)
        h = a_t * h + (dt * ut)[:, None] * bt[None, :]
        y_t = jnp.sum(h * ct[None, :], axis=1)                    # (TC,)
        y_ref[0, t, :] = y_t
        return h

    h = lax.fori_loop(0, ts, t_step, h)
    h_scratch[...] = h

    @pl.when(step == n_steps - 1)
    def _final():
        hT_ref[0] = h_scratch[...]


@functools.partial(
    jax.jit, static_argnames=("tile_c", "tile_s", "interpret")
)
def mamba_scan_pallas(
    delta: Array,   # (B, S, C) f32
    u: Array,       # (B, S, C) f32
    A: Array,       # (C, N) f32
    Bmat: Array,    # (B, S, N) f32
    Cmat: Array,    # (B, S, N) f32
    h0: Array,      # (B, C, N) f32
    *,
    tile_c: int = 512,
    tile_s: int = 256,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """Fused selective scan: returns (y (B, S, C), h_final (B, C, N))."""
    B, S, C = delta.shape
    N = A.shape[1]
    tile_c = min(tile_c, C)
    tile_s = min(tile_s, S)
    pc, ps = (-C) % tile_c, (-S) % tile_s
    if pc:
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pc)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pc)))
        A = jnp.pad(A, ((0, pc), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pc), (0, 0)))
    if ps:
        # identity steps: delta = 0 -> h unchanged, y rows discarded
        delta = jnp.pad(delta, ((0, 0), (0, ps), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, ps), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, ps), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, ps), (0, 0)))
    Sp, Cp = S + ps, C + pc
    n_steps = Sp // tile_s
    grid = (B, Cp // tile_c, n_steps)

    out = pl.pallas_call(
        functools.partial(
            _mamba_scan_kernel, ts=tile_s, n_steps=n_steps
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_s, tile_c), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, tile_s, tile_c), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((tile_c, N), lambda b, c, s: (c, 0)),
            pl.BlockSpec((1, tile_s, N), lambda b, c, s: (b, s, 0)),
            pl.BlockSpec((1, tile_s, N), lambda b, c, s: (b, s, 0)),
            pl.BlockSpec((1, tile_c, N), lambda b, c, s: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_s, tile_c), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, tile_c, N), lambda b, c, s: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Cp), delta.dtype),
            jax.ShapeDtypeStruct((B, Cp, N), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((tile_c, N), jnp.float32)],
        interpret=interpret,
    )(delta, u, A, Bmat, Cmat, h0)
    y, hT = out
    return y[:, :S, :C], hT[:, :C, :]
