"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` mirrors its kernel's public semantics exactly (same shapes,
same dtypes, same window/band conventions) using only ``jax.numpy`` — these
are the references the shape/dtype sweep tests assert_allclose against.
They delegate to the core library, which is itself validated against the
loop-based paper transcription in ``repro.core.oracle``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import envelopes as _env
from repro.core import lower_bounds as _lb
from repro.core.dtw import dtw as _dtw_fn
from repro.core.dtw import dtw_band_blocked as _dtw_blocked
from repro.kernels import tiling as _tiling

Array = jax.Array


def envelope_ref(b: Array, w: int) -> tuple[Array, Array]:
    """``(N, L) -> ((N, L), (N, L))`` upper/lower envelopes."""
    return _env.envelope(b, w)


def lb_keogh_ref(q: Array, u: Array, lo: Array) -> Array:
    """``(Q, L) x (C, L) envelopes -> (Q, C)``."""
    return _lb.lb_keogh_matrix(q, u, lo)


def lb_enhanced_ref(
    q: Array, c: Array, u: Array, lo: Array, w: int, v: int,
    *, live: Array | None = None, bands_only: bool = False,
) -> Array:
    """``(Q, L) x (C, L) -> (Q, C)`` LB_ENHANCED^V (or bands-only tier).

    ``live`` mirrors the cross-block kernel's per-candidate liveness
    input: dead candidates return ``-inf`` down their whole column.  The
    reference computes everything and masks — the *semantics* of
    skipping, which is all an oracle owes.
    """
    if bands_only:
        fn = jax.vmap(
            jax.vmap(_lb.lb_enhanced_bands, (None, 0, None, None)),
            (0, None, None, None),
        )
        out = fn(q, c, w, v)
    else:
        out = _lb.lb_enhanced_matrix(q, c, u, lo, w, v)
    if live is not None:
        live = jnp.broadcast_to(
            jnp.asarray(live), (out.shape[1],)
        ).astype(bool)
        out = jnp.where(live[None, :], out, -jnp.inf)
    return out


def lb_enhanced_pairwise_ref(
    q: Array, c: Array, u: Array, lo: Array, w: int, v: int,
    *, live: Array | None = None, bands_only: bool = False,
) -> Array:
    """Pairwise ``(P, L) x (P, L) -> (P,)`` LB_ENHANCED^V bounds.

    The packed survivor layout of the staged cascade's tier 2: row ``p``
    of the query batch pairs with row ``p`` of the candidate batch (the
    diagonal of the cross-block shape, never the full block).

    ``live`` mirrors the kernel's per-slot liveness input: dead slots
    return ``-inf`` (the caller's scatter-max identity).  The reference
    computes everything and masks — the *semantics* of skipping, which is
    all an oracle owes.
    """
    if bands_only:
        fn = jax.vmap(_lb.lb_enhanced_bands, (0, 0, None, None))
        out = fn(q, c, w, v)
    else:
        fn = jax.vmap(_lb.lb_enhanced_env, (0, 0, 0, 0, None, None))
        out = fn(q, c, u, lo, w, v)
    if live is not None:
        live = jnp.broadcast_to(jnp.asarray(live), out.shape).astype(bool)
        out = jnp.where(live, out, -jnp.inf)
    return out


def sketch_bound_ref(
    qbar: Array, sk_lo: Array, sk_hi: Array, sk_scale: Array,
    seg_sizes: Array,
) -> Array:
    """``(Q, S) f32 x (N, S) int8 -> (Q, N)`` tier-(-1) sketch bounds.

    The quantised segment-reduced LB_Keogh (see search/index.py for the
    layout and admissibility argument), in the same *scaled-units*
    formulation as the Pallas kernel (kernels/sketch.py): the query means
    are divided by ``sk_scale`` and ``sk_scale^2`` folds into the
    per-segment Cauchy-Schwarz weights, so the int8 features are compared
    without dequantising — kernel/oracle parity is exact up to summation
    order.
    """
    scale = jnp.asarray(sk_scale, jnp.float32)
    qs = jnp.asarray(qbar, jnp.float32) / scale
    wseg = jnp.asarray(seg_sizes, jnp.float32) * scale * scale    # (S,)
    lo = sk_lo.astype(jnp.float32)
    hi = sk_hi.astype(jnp.float32)
    d = jnp.maximum(
        jnp.maximum(qs[:, None, :] - hi[None, :, :],
                    lo[None, :, :] - qs[:, None, :]),
        0.0,
    )
    return jnp.sum(wseg * d * d, axis=-1)


def dtw_band_ref(
    a: Array, b: Array, w: int | None = None, cutoff: Array | None = None,
    *, row_block: int | None = None, perm: Array | None = None,
    tile_p: int | None = None,
) -> Array:
    """Pairwise banded DTW ``(P, L), (P, L) -> (P,)``.

    ``cutoff`` is an optional per-pair early-abandon threshold with the
    same semantics as the Pallas kernel: exact below the cutoff, ``>=
    cutoff`` (normally +inf) otherwise.  Abandon decisions are made on the
    same *row-block boundaries* as the kernel's early-exit grid (the
    shared ``row_block_policy``), so the two stay oracle-comparable even
    at the abandon boundary.

    ``perm`` mirrors the kernel op's pair-packing gather (gather rows,
    compute, scatter back).  Lane results are independent of batch order,
    so it is a semantic no-op here too — accepted so the engine can thread
    one call shape through both the Pallas and the reference DTW paths.

    ``tile_p`` mirrors the op's pair-tile cap the same way: tile size is
    packing geometry with no per-lane effect, so the reference accepts
    and ignores it — one call shape for the scheduler's per-round tile
    hint on both dispatch paths.
    """
    del tile_p                      # packing geometry only — no-op here
    if perm is not None:
        return _tiling.apply_pair_perm(
            lambda x, y, c: dtw_band_ref(x, y, w, c, row_block=row_block),
            perm, a, b, cutoff,
        )
    if cutoff is None:
        return jax.vmap(_dtw_fn, (0, 0, None))(a, b, w)
    cutoff = jnp.broadcast_to(jnp.asarray(cutoff, a.dtype), (a.shape[0],))
    return _dtw_blocked(a, b, w, cutoff, row_block=row_block)
