"""Pallas TPU kernel: pairwise LB_ENHANCED^V over packed survivor batches.

The staged cascade's tier-2 refinement (search/cascade.py) gather-compacts
its survivors into *paired* ``(P, L)`` batches — row ``p`` of the query
batch goes with row ``p`` of the candidate batch — which is the transpose
of the problem the cross-block kernel (lb_enhanced.py) solves: there every
query row meets every candidate row and the output is a ``(TQ, TC)``
block.  Running the cross-block kernel on compacted survivors would pay
``TQ x TC`` work for a diagonal's worth of answers, so this kernel
specialises the *pairwise* shape instead: one ``(TP, L)`` tile of queries,
candidates and candidate envelopes in, one ``(TP,)`` vector of bounds out,
a single VMEM round trip per tile.

Band structure is identical to the cross-block kernel (paper SS III):
band ``i < nb`` is L-shaped with arm width ``i + 1 <= nb``, and because
``nb = min(L/2, W, V)`` is a tiny compile-time constant the two arms are
*contiguous column prefixes/suffixes*: the left band ``bi`` is the
columns ``[0, bi]`` against column ``bi`` (and its transpose), the right
band the mirror around ``L - 1``.  Each band is therefore two
``(TP, bi + 1)`` slices, an elementwise min, and a lane reduction — no
per-cell column indexing (the per-cell form emitted O(nb^2) scalar-column
ops, which is also why the kernel used to lose to the fused jnp path at
the bench shape).  Everything is elementwise in the pair axis, so the
whole tile is one batch of VPU ops.

Per-slot liveness (``live``): the global survivor budget
(search/distributed.py) allocates per-query *refine limits* over the
packed slots; slots past the limit keep their tier-0/1 bound, so
computing them is pure waste.  ``live`` threads that allocation into the
kernel as a per-slot input: dead slots emit ``-inf`` (the identity of the
caller's scatter-max), and — the point — a pair tile whose slots are
*all* dead skips the band/bridge compute entirely, via the same SMEM-flag
``pl.when`` mechanism the DTW tiles use for their liveness exit.  The
compacted packing keeps one query's slots contiguous, so light-shard
queries produce whole dead tiles and the budget allocation turns into
genuinely skipped work, not masked outputs.

VMEM: q/c/u/lo are ``4 * TP * L`` f32 plus ``O(TP)`` accumulators.
TP=128, L=4096 -> ~8.4 MB; ``tile_p`` auto-shrinks (multiples of 8) to
stay inside ``_VMEM_BUDGET`` for longer series.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import pick_pair_tile

Array = jax.Array

_INF = float(jnp.inf)
_VMEM_BUDGET = 8 * 2**20           # bytes for the four (TP, L) operands


def _bands_and_bridge(q_ref, c_ref, u_ref, l_ref, *, nb: int,
                      bands_only: bool, dt):
    """(TP,) LB_ENHANCED^V accumulator for one pair tile (shared by the
    live-gated and ungated kernel bodies)."""
    q = q_ref[...]                                      # (TP, L)
    c = c_ref[...]
    L = q.shape[1]
    acc = jnp.zeros((q.shape[0],), dtype=dt)
    # --- elastic bands: each arm is a contiguous column slice ---
    for bi in range(nb):
        ir = L - 1 - bi
        # left band bi: cells (a_t, b_bi) and (a_bi, b_t) for t <= bi
        dl1 = q[:, :bi + 1] - c[:, bi:bi + 1]
        dl2 = q[:, bi:bi + 1] - c[:, :bi + 1]
        ml = jnp.min(jnp.minimum(dl1 * dl1, dl2 * dl2), axis=-1)
        # right band (mirror around L-1): columns [ir, L)
        dr1 = q[:, ir:] - c[:, ir:ir + 1]
        dr2 = q[:, ir:ir + 1] - c[:, ir:]
        mr = jnp.min(jnp.minimum(dr1 * dr1, dr2 * dr2), axis=-1)
        acc = acc + ml + mr
    # --- Keogh bridge over [nb, L - nb) ---
    if not bands_only:
        qb = q[:, nb:L - nb]
        over = jnp.maximum(qb - u_ref[:, nb:L - nb], 0.0)
        under = jnp.maximum(l_ref[:, nb:L - nb] - qb, 0.0)
        acc = acc + jnp.sum(over * over + under * under, axis=-1)
    return acc


def _lb_enhanced_pairwise_kernel(
    q_ref, c_ref, u_ref, l_ref, out_ref, *, nb: int, bands_only: bool
):
    out_ref[...] = _bands_and_bridge(
        q_ref, c_ref, u_ref, l_ref, nb=nb, bands_only=bands_only,
        dt=out_ref.dtype,
    )


def _lb_enhanced_pairwise_kernel_live(
    q_ref, c_ref, u_ref, l_ref, live_ref, out_ref, flag_ref, *, nb: int,
    bands_only: bool
):
    """Live-gated tile: dead slots emit -inf, all-dead tiles skip the
    band/bridge compute entirely (SMEM flag + ``pl.when``, the DTW tiles'
    liveness mechanism)."""
    live = live_ref[...] != 0                           # (TP,)
    flag_ref[0] = jnp.any(live).astype(jnp.int32)
    out_ref[...] = jnp.full(out_ref.shape, -_INF, out_ref.dtype)

    @pl.when(flag_ref[0] == 1)
    def _compute():
        acc = _bands_and_bridge(
            q_ref, c_ref, u_ref, l_ref, nb=nb, bands_only=bands_only,
            dt=out_ref.dtype,
        )
        out_ref[...] = jnp.where(live, acc, -_INF)


@functools.partial(
    jax.jit,
    static_argnames=("w", "v", "bands_only", "tile_p", "interpret"),
)
def lb_enhanced_pairwise_pallas(
    q: Array,
    c: Array,
    u: Array,
    lo: Array,
    w: int,
    v: int,
    *,
    live: Array | None = None,
    bands_only: bool = False,
    tile_p: int = 128,
    interpret: bool = False,
) -> Array:
    """``(P, L) x (P, L) -> (P,)`` pairwise LB_ENHANCED^V bounds.

    ``live`` (optional ``(P,)`` bool/int) marks which packed slots are
    worth refining: dead slots return ``-inf`` and fully-dead pair tiles
    skip their compute (module docstring).  ``None`` refines every slot.
    """
    P, L = q.shape
    nb = max(0, min(L // 2, w, v))
    # auto-shrink the pair tile so the four operands fit VMEM
    tile_p = pick_pair_tile(tile_p, P, 4 * L * 4, _VMEM_BUDGET)
    if live is not None:
        live = jnp.broadcast_to(jnp.asarray(live), (P,)).astype(jnp.int32)
    pp = (-P) % tile_p
    if pp:
        q = jnp.pad(q, ((0, pp), (0, 0)))
        c = jnp.pad(c, ((0, pp), (0, 0)))
        u = jnp.pad(u, ((0, pp), (0, 0)), constant_values=_INF)
        lo = jnp.pad(lo, ((0, pp), (0, 0)), constant_values=-_INF)
        if live is not None:
            # pad slots are dead, so they never hold a tile's flag up
            live = jnp.pad(live, (0, pp))
    Pp = P + pp
    out_shape = jax.ShapeDtypeStruct((Pp,), q.dtype)
    row_spec = pl.BlockSpec((tile_p, L), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile_p,), lambda i: (i,))
    # single-tile batches drop the grid entirely: the tile is the whole
    # problem, so the grid scaffolding (index maps, per-step block
    # slicing) is pure overhead — this is what puts the kernel ahead of
    # the fused jnp path at the bench shape (P=128, L=256)
    single = Pp == tile_p
    if live is None:
        kern = functools.partial(
            _lb_enhanced_pairwise_kernel, nb=nb, bands_only=bands_only
        )
        if single:
            out = pl.pallas_call(kern, out_shape=out_shape,
                                 interpret=interpret)(q, c, u, lo)
        else:
            out = pl.pallas_call(
                kern,
                grid=(Pp // tile_p,),
                in_specs=[row_spec] * 4,
                out_specs=out_spec,
                out_shape=out_shape,
                interpret=interpret,
            )(q, c, u, lo)
    else:
        kern = functools.partial(
            _lb_enhanced_pairwise_kernel_live, nb=nb, bands_only=bands_only
        )
        scratch = [pltpu.SMEM((1,), jnp.int32)]
        if single:
            out = pl.pallas_call(
                kern, out_shape=out_shape, scratch_shapes=scratch,
                interpret=interpret,
            )(q, c, u, lo, live)
        else:
            out = pl.pallas_call(
                kern,
                grid=(Pp // tile_p,),
                in_specs=[row_spec] * 4
                + [pl.BlockSpec((tile_p,), lambda i: (i,))],
                out_specs=out_spec,
                out_shape=out_shape,
                scratch_shapes=scratch,
                interpret=interpret,
            )(q, c, u, lo, live)
    return out[:P]
