"""Pallas TPU kernel: pairwise LB_ENHANCED^V over packed survivor batches.

The staged cascade's tier-2 refinement (search/cascade.py) gather-compacts
its survivors into *paired* ``(P, L)`` batches — row ``p`` of the query
batch goes with row ``p`` of the candidate batch — which is the transpose
of the problem the cross-block kernel (lb_enhanced.py) solves: there every
query row meets every candidate row and the output is a ``(TQ, TC)``
block.  Running the cross-block kernel on compacted survivors would pay
``TQ x TC`` work for a diagonal's worth of answers, so this kernel
specialises the *pairwise* shape instead: one ``(TP, L)`` tile of queries,
candidates and candidate envelopes in, one ``(TP,)`` vector of bounds out,
a single VMEM round trip per tile.

Band structure is identical to the cross-block kernel (paper SS III):
band ``i < nb`` is L-shaped with arm width ``i + 1 <= nb``, and because
``nb = min(L/2, W, V)`` is a tiny compile-time constant the two arms
unroll into ``O(nb^2)`` static column slices over the lane axis.  Unlike
the cross-block kernel there is no per-query row loop — every band cell
and the Keogh bridge are elementwise in the pair axis, so the whole tile
is one batch of VPU ops.

VMEM: q/c/u/lo are ``4 * TP * L`` f32 plus ``O(TP)`` accumulators.
TP=128, L=4096 -> ~8.4 MB; ``tile_p`` auto-shrinks (multiples of 8) to
stay inside ``_VMEM_BUDGET`` for longer series.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import pick_pair_tile

Array = jax.Array

_INF = float(jnp.inf)
_VMEM_BUDGET = 8 * 2**20           # bytes for the four (TP, L) operands


def _lb_enhanced_pairwise_kernel(
    q_ref, c_ref, u_ref, l_ref, out_ref, *, nb: int, bands_only: bool
):
    q = q_ref[...]                                      # (TP, L)
    c = c_ref[...]
    L = q.shape[1]
    acc = jnp.zeros((q.shape[0],), dtype=out_ref.dtype)
    # --- elastic bands: unrolled static column slices (nb is tiny) ---
    for bi in range(nb):
        ir = L - 1 - bi
        ml = jnp.full_like(acc, _INF)
        mr = jnp.full_like(acc, _INF)
        for t in range(bi + 1):
            # left band bi: cells (a_{bi-t}, b_bi) and (a_bi, b_{bi-t})
            dl1 = q[:, bi - t] - c[:, bi]
            dl2 = q[:, bi] - c[:, bi - t]
            ml = jnp.minimum(ml, jnp.minimum(dl1 * dl1, dl2 * dl2))
            # right band (mirror around L-1)
            dr1 = q[:, ir + t] - c[:, ir]
            dr2 = q[:, ir] - c[:, ir + t]
            mr = jnp.minimum(mr, jnp.minimum(dr1 * dr1, dr2 * dr2))
        acc = acc + ml + mr
    # --- Keogh bridge over [nb, L - nb) ---
    if not bands_only:
        qb = q[:, nb:L - nb]
        over = jnp.maximum(qb - u_ref[:, nb:L - nb], 0.0)
        under = jnp.maximum(l_ref[:, nb:L - nb] - qb, 0.0)
        acc = acc + jnp.sum(over * over + under * under, axis=-1)
    out_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("w", "v", "bands_only", "tile_p", "interpret"),
)
def lb_enhanced_pairwise_pallas(
    q: Array,
    c: Array,
    u: Array,
    lo: Array,
    w: int,
    v: int,
    *,
    bands_only: bool = False,
    tile_p: int = 128,
    interpret: bool = False,
) -> Array:
    """``(P, L) x (P, L) -> (P,)`` pairwise LB_ENHANCED^V bounds."""
    P, L = q.shape
    nb = max(0, min(L // 2, w, v))
    # auto-shrink the pair tile so the four operands fit VMEM
    tile_p = pick_pair_tile(tile_p, P, 4 * L * 4, _VMEM_BUDGET)
    pp = (-P) % tile_p
    if pp:
        q = jnp.pad(q, ((0, pp), (0, 0)))
        c = jnp.pad(c, ((0, pp), (0, 0)))
        u = jnp.pad(u, ((0, pp), (0, 0)), constant_values=_INF)
        lo = jnp.pad(lo, ((0, pp), (0, 0)), constant_values=-_INF)
    Pp = P + pp
    out = pl.pallas_call(
        functools.partial(
            _lb_enhanced_pairwise_kernel, nb=nb, bands_only=bands_only
        ),
        grid=(Pp // tile_p,),
        in_specs=[pl.BlockSpec((tile_p, L), lambda i: (i, 0))] * 4,
        out_specs=pl.BlockSpec((tile_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), q.dtype),
        interpret=interpret,
    )(q, c, u, lo)
    return out[:P]
