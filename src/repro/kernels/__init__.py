"""Pallas TPU kernels for the paper's compute hot-spots.

The paper optimises exactly one thing — the per-pair cost of lower-bounded
NN-DTW search — so the kernels here cover that pipeline end to end:

  * envelope.py        — Sakoe-Chiba envelopes (Eqs. 5-6), prefix-doubling
  * lb_keogh.py        — batched LB_KEOGH blocks (Eq. 7)
  * lb_enhanced.py     — fused LB_ENHANCED^V, cross-block (Q, L)x(C, L)
  * lb_enhanced_pairwise.py — fused LB_ENHANCED^V, packed (P, L) survivor
    pairs (the staged cascade's tier-2 shape)
  * dtw_band.py        — banded DTW verification, band-packed wavefront
    with row-block early exit
  * mamba_scan.py      — fused Mamba selective scan (substrate hot-spot)
  * flash_attention.py — fused attention forward (substrate hot-spot)

``ops.py`` holds the jitted public wrappers (interpret=True on CPU,
custom-vjp training wrappers for the fused kernels); ``ref.py`` the
pure-jnp oracles the tests sweep against.
"""

from repro.kernels.ops import (
    dtw_band_op,
    envelope_op,
    flash_attention_op,
    lb_enhanced_op,
    lb_enhanced_pairwise_op,
    lb_keogh_op,
    mamba_scan_op,
)

__all__ = [
    "dtw_band_op",
    "envelope_op",
    "flash_attention_op",
    "lb_enhanced_op",
    "lb_enhanced_pairwise_op",
    "lb_keogh_op",
    "mamba_scan_op",
]
