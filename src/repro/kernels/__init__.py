"""Pallas TPU kernels for the paper's compute hot-spots.

The paper optimises exactly one thing — the per-pair cost of lower-bounded
NN-DTW search — so the kernels here cover that pipeline end to end:

  * envelope.py        — Sakoe-Chiba envelopes (Eqs. 5-6), prefix-doubling
  * lb_keogh.py        — batched LB_KEOGH blocks (Eq. 7)
  * lb_enhanced.py     — fused elastic-band + bridge LB_ENHANCED^V (Eq. 14)
  * dtw_band.py        — banded DTW verification, lane-parallel wavefront
  * mamba_scan.py      — fused Mamba selective scan (substrate hot-spot)
  * flash_attention.py — fused attention forward (substrate hot-spot)

``ops.py`` holds the jitted public wrappers (interpret=True on CPU,
custom-vjp training wrappers for the fused kernels); ``ref.py`` the
pure-jnp oracles the tests sweep against.
"""

from repro.kernels.ops import (
    dtw_band_op,
    envelope_op,
    flash_attention_op,
    lb_enhanced_op,
    lb_keogh_op,
    mamba_scan_op,
)

__all__ = [
    "dtw_band_op",
    "envelope_op",
    "flash_attention_op",
    "lb_enhanced_op",
    "lb_keogh_op",
    "mamba_scan_op",
]
