"""Shared VMEM tile-sizing and pair-packing policy for the pair-batched
kernels.

Every pair-batched kernel (dtw_band, lb_enhanced_pairwise) tiles the pair
axis in sublane multiples of 8 and auto-shrinks the tile so its per-pair
VMEM footprint stays inside the kernel's budget — one policy, defined
once, so a change to the floor or the rounding applies everywhere.

Pair-packing permutation: which lanes share a pair tile is a *scheduling*
decision (the engine's bound-ordered verification schedule argsorts each
round's flat batch so doomed pairs cluster into the same tiles — see
search/engine.py), but the *mechanism* lives here: gather the operand rows
by ``perm`` before the kernel, scatter the outputs back after.  Per-lane
results are independent of tile composition, so the permutation is
result-invariant by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def permute_pairs(perm: Array, *arrays):
    """Gather each array's pair axis (axis 0) by ``perm``; ``None`` entries
    pass through (an absent per-pair operand, e.g. a missing cutoff)."""
    return tuple(None if x is None else x[perm] for x in arrays)


def unpermute_pairs(perm: Array, out: Array) -> Array:
    """Scatter a packed kernel output back to pre-``perm`` pair order
    (the inverse gather: ``result[perm[i]] = out[i]``)."""
    return jnp.zeros_like(out).at[perm].set(out)


def apply_pair_perm(fn, perm: Array, a: Array, b: Array,
                    cutoff: Array | None) -> Array:
    """The whole perm round trip for a pair-batched call: broadcast a
    scalar cutoff to per-pair (scalars are legal without ``perm``, so they
    must stay legal with it), gather the operands, run
    ``fn(a, b, cutoff)``, scatter the output back.  One definition shared
    by the Pallas op and the jnp reference so their ``perm=`` semantics
    cannot diverge."""
    if cutoff is not None:
        cutoff = jnp.broadcast_to(jnp.asarray(cutoff, a.dtype),
                                  (a.shape[0],))
    pa, pb, pcut = permute_pairs(perm, a, b, cutoff)
    return unpermute_pairs(perm, fn(pa, pb, pcut))


def pick_pair_tile(tile_p: int, P: int, per_row_bytes: int,
                   budget_bytes: int) -> int:
    """Largest pair-tile <= ``tile_p`` (multiple of 8, floor 8) whose
    ``per_row_bytes`` footprint fits ``budget_bytes``, clamped so a short
    batch is a single tile."""
    tile_p = min(tile_p, max(8, (budget_bytes // per_row_bytes) // 8 * 8))
    return min(tile_p, round_up(P, 8))


def sketch_tile_c(Q: int, S: int, C: int, budget_bytes: int) -> int:
    """Candidate-tile width for the sketch kernel (kernels/sketch.py).

    Per candidate: ``2 S`` int8 feature cells, their f32 in-register
    casts (``8 S``), and the ``(Q, tile)`` output column (``4 Q``).
    Lane multiples of 128, floor 128, clamped so a short store is a
    single tile.
    """
    per_c = 2 * S + 8 * S + 4 * Q
    tile = max(128, (budget_bytes // max(1, per_c)) // 128 * 128)
    return min(tile, round_up(C, 128))


def sched_pair_tile(P: int, default: int = 128) -> int:
    """Pair-tile size for a *bound-ordered* verification round.

    Under the engine's ascending-bound packing the doomed tail of a round
    clusters into contiguous lanes, but the cluster boundary rarely lands
    on a tile boundary — a tile exits only when *every* lane in it is
    dead, so at the kernel's default 128-lane tiles one straggler holds
    31 doomed neighbours hostage.  Smaller tiles localise the exit to the
    cluster boundary at the cost of more grid steps; this policy scales
    the tile with the round size so big rounds (absolute cluster sizes
    grow with ``P``) keep wide tiles while typical engine rounds
    (``P = Q * verify_chunk`` ~ a few hundred) drop to 32 lanes.  Unsorted
    (``"index"``) rounds gain nothing from finer granularity and keep the
    kernel default.  Tile size is packing geometry only — per-lane DTW
    values, and therefore results and ``n_dtw``, are invariant under it.
    """
    return max(8, min(default, round_up(max(32, P // 16), 8)))


# minimum streaming row block: one anti-diagonal sweep per DMA round trip
# is all overhead, so the block never shrinks below 64 steps
_STREAM_MIN_BLOCK = 64

# Fixed per-block pipeline cost of the streaming grid, expressed in
# single-lane-width anti-diagonal sweep steps: issuing a block's two
# operand-window copies plus pipeline warm-up costs about as much as this
# many steps of band-width-128 sweep work.  Measured from the committed
# dtw_band_stream_L2048_* vs *_resident paired timings
# (benchmarks/kernel_bench.py): the pipeline overhead that put streaming
# at ~0.95x resident under the old hard-coded 1024-step floor is ~4
# block issues over 4095 steps.
_STREAM_DMA_ISSUE_STEPS = 64

# per-block fixed cost must stay under this fraction of the block's
# sweep work for the pipeline to track the resident grid within ~10%
_STREAM_OVERHEAD_FRAC = 1.0 / 16.0


def stream_pref_block(
    wb: int,
    *,
    dma_issue_steps: int = _STREAM_DMA_ISSUE_STEPS,
    overhead_frac: float = _STREAM_OVERHEAD_FRAC,
) -> int:
    """Preferred streaming row-block floor for band halfwidth ``wb``.

    Replaces the old hard-coded 1024-step floor: the block only needs to
    be large enough that the fixed per-block pipeline cost
    (``dma_issue_steps``, measured — see the constant above) stays under
    ``overhead_frac`` of the block's sweep work.  A step sweeps
    ``2 wb + 1`` band lanes, so wide bands do more work per step and
    amortise the issue cost with *smaller* blocks — narrow bands
    (``2 wb + 1 <= 128``, one VPU lane group) still get the old
    1024-step floor, which falls out of the same arithmetic.  Abandon
    boundaries moving with the floor never changes values (frontier
    minima are monotone — core/dtw.py), only how soon a dead tile stops.
    """
    work_per_step = max(1.0, (2 * wb + 1) / 128.0)
    need = dma_issue_steps / (overhead_frac * work_per_step)
    return round_up(max(_STREAM_MIN_BLOCK, int(need)), _STREAM_MIN_BLOCK)


def stream_geometry(
    L: int,
    wb: int,
    tile_p: int,
    P: int,
    budget_bytes: int,
    row_block: int | None = None,
    pref_block: int | None = None,
) -> tuple[int, int] | None:
    """Per-block working-set budget for the streaming DTW kernel.

    The streaming kernel's VMEM footprint is *per row block*, not per
    sweep: 2 double-buffer slots x 2 operand windows of ``Wwin = R + Wb``
    lanes plus the frontier/temporary state of ``~8 Wb`` lanes, all times
    the pair tile.  Returns ``(tile, R)`` — the largest pair tile (sublane
    multiples, floor 8) and row block (64-step multiples) that fit
    ``budget_bytes`` — or ``None`` when even the minimum block at the
    sublane floor cannot fit (the band state itself exceeds VMEM; ops.py
    falls back to the jnp reference there).

    The default block is the shared ``row_block_policy`` (abandon
    boundaries match the jnp reference) floored at ``pref_block`` steps —
    by default the band-width-aware ``stream_pref_block(wb)`` policy:
    short sweeps amortise the fixed per-block pipeline cost (DMA issue +
    warm-up) poorly, so the floor is sized so that cost stays a bounded
    fraction of each block's sweep work.  Callers with their own
    measured issue cost pass ``pref_block`` explicitly.
    """
    from repro.core.dtw import row_block_policy

    D = 2 * L - 1
    pref = stream_pref_block(wb) if pref_block is None else pref_block
    R = row_block if row_block is not None else max(
        row_block_policy(L), min(pref, D))
    R = max(1, min(R, D))
    while True:
        Wwin = round_up(R + Wb_pad(wb), 128)
        per_row = (4 * Wwin + 8 * Wb_pad(wb)) * 4
        tile = pick_pair_tile(tile_p, P, per_row, budget_bytes)
        if tile * per_row <= budget_bytes:
            return tile, R
        if R <= _STREAM_MIN_BLOCK:
            return None
        R = max(_STREAM_MIN_BLOCK, round_up(R // 2, _STREAM_MIN_BLOCK))


def Wb_pad(wb: int) -> int:
    """Lane-padded band-state width ``2 wb + 1`` (128-lane multiples) —
    one definition shared by the resident/streaming kernels and the
    budget policies above."""
    return round_up(2 * wb + 1, 128)
