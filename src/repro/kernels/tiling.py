"""Shared VMEM tile-sizing and pair-packing policy for the pair-batched
kernels.

Every pair-batched kernel (dtw_band, lb_enhanced_pairwise) tiles the pair
axis in sublane multiples of 8 and auto-shrinks the tile so its per-pair
VMEM footprint stays inside the kernel's budget — one policy, defined
once, so a change to the floor or the rounding applies everywhere.

Pair-packing permutation: which lanes share a pair tile is a *scheduling*
decision (the engine's bound-ordered verification schedule argsorts each
round's flat batch so doomed pairs cluster into the same tiles — see
search/engine.py), but the *mechanism* lives here: gather the operand rows
by ``perm`` before the kernel, scatter the outputs back after.  Per-lane
results are independent of tile composition, so the permutation is
result-invariant by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def permute_pairs(perm: Array, *arrays):
    """Gather each array's pair axis (axis 0) by ``perm``; ``None`` entries
    pass through (an absent per-pair operand, e.g. a missing cutoff)."""
    return tuple(None if x is None else x[perm] for x in arrays)


def unpermute_pairs(perm: Array, out: Array) -> Array:
    """Scatter a packed kernel output back to pre-``perm`` pair order
    (the inverse gather: ``result[perm[i]] = out[i]``)."""
    return jnp.zeros_like(out).at[perm].set(out)


def apply_pair_perm(fn, perm: Array, a: Array, b: Array,
                    cutoff: Array | None) -> Array:
    """The whole perm round trip for a pair-batched call: broadcast a
    scalar cutoff to per-pair (scalars are legal without ``perm``, so they
    must stay legal with it), gather the operands, run
    ``fn(a, b, cutoff)``, scatter the output back.  One definition shared
    by the Pallas op and the jnp reference so their ``perm=`` semantics
    cannot diverge."""
    if cutoff is not None:
        cutoff = jnp.broadcast_to(jnp.asarray(cutoff, a.dtype),
                                  (a.shape[0],))
    pa, pb, pcut = permute_pairs(perm, a, b, cutoff)
    return unpermute_pairs(perm, fn(pa, pb, pcut))


def pick_pair_tile(tile_p: int, P: int, per_row_bytes: int,
                   budget_bytes: int) -> int:
    """Largest pair-tile <= ``tile_p`` (multiple of 8, floor 8) whose
    ``per_row_bytes`` footprint fits ``budget_bytes``, clamped so a short
    batch is a single tile."""
    tile_p = min(tile_p, max(8, (budget_bytes // per_row_bytes) // 8 * 8))
    return min(tile_p, round_up(P, 8))
