"""Shared VMEM tile-sizing policy for the pair-batched kernels.

Every pair-batched kernel (dtw_band, lb_enhanced_pairwise) tiles the pair
axis in sublane multiples of 8 and auto-shrinks the tile so its per-pair
VMEM footprint stays inside the kernel's budget — one policy, defined
once, so a change to the floor or the rounding applies everywhere.
"""

from __future__ import annotations


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pick_pair_tile(tile_p: int, P: int, per_row_bytes: int,
                   budget_bytes: int) -> int:
    """Largest pair-tile <= ``tile_p`` (multiple of 8, floor 8) whose
    ``per_row_bytes`` footprint fits ``budget_bytes``, clamped so a short
    batch is a single tile."""
    tile_p = min(tile_p, max(8, (budget_bytes // per_row_bytes) // 8 * 8))
    return min(tile_p, round_up(P, 8))
