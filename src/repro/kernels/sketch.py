"""Pallas TPU kernel: tier-(-1) quantised sketch bounds, Q x N in one pass.

The sketch tier (search/pipeline.py) is the first tier whose *memory
format* differs from the store's: instead of the ``(N, L)`` float32
series, each candidate contributes ``2 S`` int8 cells — the outward-
quantised per-segment means of its w-envelope (search/index.py documents
the layout and the admissibility argument).  At ``S = 16`` that is 32
bytes/candidate, so a 10M-candidate sketch store is ~320 MB and stays
VMEM/HBM-resident where the raw series cannot; the kernel streams
candidate tiles of the int8 features past a resident query block and
emits the full ``(Q, N)`` bound matrix in one pass.

Scaled-units formulation: rather than dequantising the features and
carrying ``scale`` into the kernel, the host pre-divides the query
segment means by ``scale`` and folds ``scale^2`` into the per-segment
Cauchy-Schwarz weights::

    qs   = qbar / scale                       (Q, S) f32
    wseg = n_j * scale^2                      (S,)  f32
    out[q, n] = sum_j wseg[j] * max(qs[q,j] - sk_hi[n,j],
                                    sk_lo[n,j] - qs[q,j], 0)^2

so the kernel touches only the int8 features (cast to f32 in-register),
one resident ``(Q, S)`` query block and one ``(1, S)`` weight row.  The
jnp reference (ref.sketch_bound_ref) computes the *same* formulation, so
kernel/oracle parity is exact up to summation order.

The segment loop is a static Python loop (``S <= 16``): each step is one
``(Q, TC)`` broadcast max + multiply-accumulate, all VPU-elementwise —
no per-cell indexing, no reductions besides the accumulate.

VMEM: per candidate tile — ``2 S`` int8 features + their f32 casts +
the ``(Q, TC)`` output column; ``tiling.sketch_tile_c`` auto-shrinks the
tile (128-lane multiples) to stay inside ``_VMEM_BUDGET``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import sketch_tile_c

Array = jax.Array

_VMEM_BUDGET = 8 * 2**20


def _sketch_kernel(qs_ref, wseg_ref, lo_ref, hi_ref, out_ref, *, S: int):
    qs = qs_ref[...]                                    # (Q, S)
    lo = lo_ref[...].astype(jnp.float32)                # (TC, S)
    hi = hi_ref[...].astype(jnp.float32)
    acc = jnp.zeros(out_ref.shape, out_ref.dtype)       # (Q, TC)
    for j in range(S):                                  # static, S <= 16
        d = jnp.maximum(
            jnp.maximum(qs[:, j:j + 1] - hi[:, j][None, :],
                        lo[:, j][None, :] - qs[:, j:j + 1]),
            0.0,
        )
        acc = acc + wseg_ref[0, j] * d * d
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def sketch_bound_pallas(
    qs: Array,
    sk_lo: Array,
    sk_hi: Array,
    wseg: Array,
    *,
    tile_c: int | None = None,
    interpret: bool = False,
) -> Array:
    """``(Q, S) x (N, S) int8 -> (Q, N)`` sketch bounds (scaled units).

    Inputs are the scaled-units operands (module docstring): ``qs`` the
    scale-divided query segment means, ``wseg`` the ``n_j * scale^2``
    weights.  ``ops.sketch_bound_op`` builds them from the raw index
    features; call that, not this, unless you already have them.
    """
    Q, S = qs.shape
    N = sk_lo.shape[0]
    tc = sketch_tile_c(Q, S, N, _VMEM_BUDGET) if tile_c is None else tile_c
    wrow = jnp.asarray(wseg, jnp.float32).reshape(1, S)
    # pad the candidate axis to a tile multiple with an *inverted*
    # envelope (lo=+127 > hi=-127): pad columns score a huge finite
    # bound and are sliced off below either way
    pc = (-N) % tc
    if pc:
        sk_lo = jnp.pad(sk_lo, ((0, pc), (0, 0)), constant_values=127)
        sk_hi = jnp.pad(sk_hi, ((0, pc), (0, 0)), constant_values=-127)
    Np = N + pc
    kern = functools.partial(_sketch_kernel, S=S)
    out_shape = jax.ShapeDtypeStruct((Q, Np), jnp.float32)
    single = Np == tc
    if single:
        out = pl.pallas_call(kern, out_shape=out_shape,
                             interpret=interpret)(qs, wrow, sk_lo, sk_hi)
    else:
        out = pl.pallas_call(
            kern,
            grid=(Np // tc,),
            in_specs=[
                pl.BlockSpec((Q, S), lambda i: (0, 0)),
                pl.BlockSpec((1, S), lambda i: (0, 0)),
                pl.BlockSpec((tc, S), lambda i: (i, 0)),
                pl.BlockSpec((tc, S), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((Q, tc), lambda i: (0, i)),
            out_shape=out_shape,
            interpret=interpret,
        )(qs, wrow, sk_lo, sk_hi)
    return out[:, :N]
