"""Pallas TPU kernel: Sakoe-Chiba window envelopes (paper Eqs. 5-6).

Layout: grid over batch tiles; each program owns a ``(TN, L)`` block of
series rows in VMEM and produces the matching upper/lower envelope blocks.
The windowed min/max uses prefix-doubling shifted reductions (log2(W) dense
vector ops) — the TPU-native replacement for Lemire's deque (DESIGN.md SS3).

VMEM budget: 3 blocks of (TN, L) f32.  With TN=8 and L=65536 that is 6 MB,
comfortably inside the ~16 MB/core VMEM of a v5e.  Longer series fall back
to the jnp path in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_NEG = float(-jnp.inf)
_POS = float(jnp.inf)


def _shift_left(x: Array, s: int, fill: float) -> Array:
    if s == 0:
        return x
    pad = jnp.full(x.shape[:-1] + (s,), fill, dtype=x.dtype)
    return jnp.concatenate([x[..., s:], pad], axis=-1)


def _shift_right(x: Array, s: int, fill: float) -> Array:
    if s == 0:
        return x
    pad = jnp.full(x.shape[:-1] + (s,), fill, dtype=x.dtype)
    return jnp.concatenate([pad, x[..., :-s]], axis=-1)


def _sliding(x: Array, k: int, op, fill: float, shift) -> Array:
    """op-reduce over windows of size ``k`` ending (shift=_shift_right) or
    starting (shift=_shift_left) at each position, clipped at the edges."""
    m = x
    p = 1
    while p * 2 <= k:
        m = op(m, shift(m, p, fill))
        p *= 2
    if p < k:
        m = op(m, shift(m, k - p, fill))
    return m


def _envelope_kernel(b_ref, u_ref, l_ref, *, w: int):
    b = b_ref[...]
    if w == 0:
        u_ref[...] = b
        l_ref[...] = b
        return
    # two one-sided windows of size w+1 overlap at i and cover [i-w, i+w];
    # min/max are idempotent so the overlap is harmless.
    k = w + 1
    u_fwd = _sliding(b, k, jnp.maximum, _NEG, _shift_left)
    u_bwd = _sliding(b, k, jnp.maximum, _NEG, _shift_right)
    u_ref[...] = jnp.maximum(u_fwd, u_bwd)
    l_fwd = _sliding(b, k, jnp.minimum, _POS, _shift_left)
    l_bwd = _sliding(b, k, jnp.minimum, _POS, _shift_right)
    l_ref[...] = jnp.minimum(l_fwd, l_bwd)


@functools.partial(jax.jit, static_argnames=("w", "tile_n", "interpret"))
def envelope_pallas(
    b: Array, w: int, *, tile_n: int = 8, interpret: bool = False
) -> tuple[Array, Array]:
    """Batched envelopes: ``(N, L) -> ((N, L) upper, (N, L) lower)``.

    Note the window-centering subtlety: ``_shift_right`` by ``w`` then a
    forward sliding window of ``2w + 1`` reproduces the two-sided window
    ``[i - w, i + w]`` with correct clipping at both series ends, entirely
    with static shifts (no gathers — Mosaic-friendly).
    """
    n, L = b.shape
    tile_n = min(tile_n, n)
    pad_n = (-n) % tile_n
    if pad_n:
        b = jnp.pad(b, ((0, pad_n), (0, 0)))
    np_, _ = b.shape
    grid = (np_ // tile_n,)
    spec = pl.BlockSpec((tile_n, L), lambda i: (i, 0))
    u, lo = pl.pallas_call(
        functools.partial(_envelope_kernel, w=min(w, L)),
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((np_, L), b.dtype),
            jax.ShapeDtypeStruct((np_, L), b.dtype),
        ],
        interpret=interpret,
    )(b)
    return u[:n], lo[:n]
