"""Jitted public entry points for the Pallas kernels.

Dispatch policy:
  * on TPU backends the kernels run compiled (interpret=False);
  * on CPU (this container) they run in ``interpret=True`` mode, which
    executes the kernel bodies in Python for bit-faithful validation;
  * shapes outside kernel limits (very long series that exceed the VMEM
    budget documented in each kernel) fall back to the pure-jnp reference,
    so the public API never fails on shape grounds.

All entry points accept/return plain arrays and are safe to ``jax.jit``
(and to call inside ``shard_map`` — see search/distributed.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dtw_band import _VMEM_BUDGET as _DTW_VMEM_BUDGET
from repro.kernels.dtw_band import dtw_band_pallas
from repro.kernels.envelope import envelope_pallas
from repro.kernels.lb_enhanced import lb_enhanced_pallas
from repro.kernels.lb_enhanced_pairwise import lb_enhanced_pairwise_pallas
from repro.kernels.lb_keogh import lb_keogh_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.sketch import sketch_bound_pallas
from repro.kernels.tiling import apply_pair_perm, stream_geometry

Array = jax.Array

# VMEM-derived shape limits (see per-kernel headers for the budgets)
_ENVELOPE_MAX_L = 65536
_LB_MAX_L = 16384
# Above this length the packed DTW operands stop being VMEM-resident and
# dtw_band_op switches to the streaming DMA-pipeline grid — there is no
# length ceiling any more, only this residency crossover.
_DTW_RESIDENT_MAX_L = 16384


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def envelope_op(b: Array, w: int) -> tuple[Array, Array]:
    """Batched Sakoe-Chiba envelopes ``(N, L) -> (U, L)`` pair."""
    b = jnp.asarray(b)
    squeeze = b.ndim == 1
    if squeeze:
        b = b[None]
    if b.shape[-1] > _ENVELOPE_MAX_L:
        u, lo = ref.envelope_ref(b, w)
    else:
        u, lo = envelope_pallas(b, w, interpret=_interpret())
    return (u[0], lo[0]) if squeeze else (u, lo)


def lb_keogh_op(q: Array, u: Array, lo: Array) -> Array:
    """``(Q, L) x (C, L) envelopes -> (Q, C)`` LB_KEOGH matrix."""
    if q.shape[-1] > _LB_MAX_L:
        return ref.lb_keogh_ref(q, u, lo)
    return lb_keogh_pallas(q, u, lo, interpret=_interpret())


def lb_enhanced_op(
    q: Array, c: Array, u: Array, lo: Array, w: int, v: int,
    *, live: Array | None = None, bands_only: bool = False,
) -> Array:
    """``(Q, L) x (C, L) -> (Q, C)`` fused LB_ENHANCED^V matrix.

    ``live`` (optional ``(C,)``) marks the candidates worth scoring: dead
    candidates return ``-inf`` for every query (the running-max identity)
    and fully-dead candidate tiles skip their compute — liveness parity
    with the pairwise kernel, so the planner can limit-mask a dense
    cross-block tier too (see kernels/lb_enhanced.py).
    """
    if q.shape[-1] > _LB_MAX_L:
        return ref.lb_enhanced_ref(
            q, c, u, lo, w, v, live=live, bands_only=bands_only
        )
    return lb_enhanced_pallas(
        q, c, u, lo, w, v, live=live, bands_only=bands_only,
        interpret=_interpret(),
    )


def lb_enhanced_pairwise_op(
    q: Array, c: Array, u: Array, lo: Array, w: int, v: int,
    *, live: Array | None = None, bands_only: bool = False,
) -> Array:
    """``(P, L) x (P, L) -> (P,)`` pairwise LB_ENHANCED^V bounds.

    The staged cascade's tier-2 shape: gather-compacted (query, candidate)
    survivor pairs, one bound per packed row (see
    kernels/lb_enhanced_pairwise.py vs the cross-block lb_enhanced.py).

    ``live`` (optional ``(P,)``) marks the slots the compaction policy
    allocated for refinement: dead slots return ``-inf`` and fully-dead
    pair tiles skip their compute — the global survivor budget's refine
    limits become skipped work, not masked outputs.
    """
    if q.shape[-1] > _LB_MAX_L:
        return ref.lb_enhanced_pairwise_ref(
            q, c, u, lo, w, v, live=live, bands_only=bands_only
        )
    return lb_enhanced_pairwise_pallas(
        q, c, u, lo, w, v, live=live, bands_only=bands_only,
        interpret=_interpret(),
    )


# the sketch kernel holds the (Q, S) query block resident per tile;
# beyond this many queries the op batches the reference instead
_SKETCH_MAX_Q = 4096


def sketch_bound_op(
    qbar: Array, sk_lo: Array, sk_hi: Array, sk_scale: Array,
    seg_sizes: Array,
) -> Array:
    """``(Q, S) f32 x (N, S) int8 -> (Q, N)`` tier-(-1) sketch bounds.

    The quantised segment-reduced LB_Keogh over the int8 PAA sketch
    store (search/index.py documents the layout; kernels/sketch.py the
    kernel).  Host-side it rewrites the operands into the kernel's
    scaled-units form — the kernel never sees ``sk_scale``.
    """
    qbar = jnp.asarray(qbar, jnp.float32)
    if qbar.shape[0] > _SKETCH_MAX_Q:
        return ref.sketch_bound_ref(qbar, sk_lo, sk_hi, sk_scale,
                                    seg_sizes)
    scale = jnp.asarray(sk_scale, jnp.float32)
    qs = qbar / scale
    wseg = jnp.asarray(seg_sizes, jnp.float32) * scale * scale
    return sketch_bound_pallas(qs, sk_lo, sk_hi, wseg,
                               interpret=_interpret())


def dtw_band_op(
    a: Array, b: Array, w: int | None = None, cutoff: Array | None = None,
    *, early_exit: bool = True, perm: Array | None = None,
    tile_p: int | None = None,
) -> Array:
    """Pairwise banded DTW ``(P, L) x (P, L) -> (P,)``.

    ``cutoff`` (optional, per-pair) early-abandons lanes whose running
    frontier minimum proves the distance exceeds it (returns +inf there).
    With ``early_exit`` (default) the kernel runs the row-block grid that
    skips whole anti-diagonal blocks once every lane in a pair tile is
    abandoned; ``early_exit=False`` is PR 1's per-step lane-poisoning
    sweep, kept for the benchmark trajectory.

    ``perm`` (optional, a permutation of ``arange(P)``) is a *pair-packing
    gather*: operand rows are gathered by ``perm`` before the kernel and
    outputs scattered back (kernels/tiling.py), so the caller chooses
    which pairs share a pair tile — the engine's bound-ordered schedule
    clusters doomed pairs so the tile-level early exit fires per cluster —
    without the kernel, or the results, changing at all.

    ``tile_p`` (optional) caps the pair-tile size — the scheduler hook
    behind ``VerificationPlan.verify_tile_p``: bound-ordered rounds pick
    smaller tiles so the liveness exit fires on cluster boundaries (see
    tiling.sched_pair_tile).  ``None`` keeps the kernel default.  Packing
    geometry only; results are invariant under it.

    Length dispatch: series up to ``_DTW_RESIDENT_MAX_L`` run the
    VMEM-resident grid; longer series run the streaming DMA pipeline
    (operands in HBM, double-buffered per-block windows — no length
    ceiling).  The streaming grid *is* the liveness grid, so past the
    crossover ``early_exit=False`` is ignored — the PR 1 baseline is a
    VMEM-resident kernel by construction and only exists below the
    crossover (benchmark it there).  Only shapes whose *band state*
    exceeds VMEM at the sublane floor (``stream_geometry`` returns None,
    e.g. w = L at L = 64k) fall back to the jnp reference, so the public
    API never fails on shape grounds.
    """
    if perm is not None:
        return apply_pair_perm(
            lambda x, y, c: dtw_band_op(x, y, w, c, early_exit=early_exit,
                                        tile_p=tile_p),
            perm, a, b, cutoff,
        )
    P, L = a.shape
    tp = 128 if tile_p is None else tile_p
    if L > _DTW_RESIDENT_MAX_L:
        wb = min(L if (w is None or w >= L) else w, L - 1)
        if stream_geometry(L, wb, tp, P, _DTW_VMEM_BUDGET) is None:
            out = ref.dtw_band_ref(a, b, w, cutoff)
        else:
            out = dtw_band_pallas(
                a, b, w, cutoff, stream=True, tile_p=tp,
                interpret=_interpret(),
            )
    else:
        out = dtw_band_pallas(
            a, b, w, cutoff, early_exit=early_exit, tile_p=tp,
            interpret=_interpret(),
        )
    # fault seam (search/guards.py): the jnp reference mirrors do NOT
    # pass through here, so the guard subsystem's degradation rerun
    # (use_pallas=False) bypasses an injected kernel fault — the
    # property tests/test_guards.py relies on.  Imported lazily:
    # kernels must stay importable without the search package
    from repro.search.guards import fault_hook

    hook = fault_hook("dtw_out")
    return out if hook is None else hook(out)


# ---------------------------------------------------------------------------
# Fused Mamba selective scan (forward = Pallas kernel; backward recomputes
# through the differentiable chunked-scan reference — same recompute policy
# the remat'd scan path uses, so training numerics are identical).
# ---------------------------------------------------------------------------

@jax.custom_vjp
def mamba_scan_op(delta, u, A, Bmat, Cmat, h0):
    """Fused selective scan: (y (B,S,C), h_final (B,C,N))."""
    return mamba_scan_pallas(delta, u, A, Bmat, Cmat, h0,
                             interpret=_interpret())


def _mamba_fwd(delta, u, A, Bmat, Cmat, h0):
    out = mamba_scan_op(delta, u, A, Bmat, Cmat, h0)
    return out, (delta, u, A, Bmat, Cmat, h0)


def _mamba_bwd(res, cts):
    from repro.models.mamba import _chunked_selective_scan

    delta, u, A, Bmat, Cmat, h0 = res
    _, vjp = jax.vjp(
        lambda d, uu, a, bm, cm, h: _chunked_selective_scan(
            d, uu, a, bm, cm, h, chunk=256
        ),
        delta, u, A, Bmat, Cmat, h0,
    )
    return vjp(cts)


mamba_scan_op.defvjp(_mamba_fwd, _mamba_bwd)


# ---------------------------------------------------------------------------
# Fused flash attention (forward = Pallas kernel; backward recomputes
# through the chunked-jnp reference, matching the remat policy).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_op(q, k, v, causal=True, window=None, score_cap=None):
    """Fused self-attention forward: (B, Sq, Hq, D) x (B, Skv, Hkv, D)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, score_cap=score_cap,
        interpret=_interpret(),
    )


def _fa_fwd(q, k, v, causal, window, score_cap):
    return flash_attention_op(q, k, v, causal, window, score_cap), (q, k, v)


def _fa_bwd(causal, window, score_cap, res, ct):
    from repro.models.attention import flash_attention

    q, k, v = res
    B, Sq = q.shape[0], q.shape[1]
    Skv = k.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    pos_k = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    _, vjp = jax.vjp(
        lambda qq, kk, vv: flash_attention(
            qq, kk, vv, pos_q, pos_k, causal=causal, window=window,
            score_cap=score_cap,
        ),
        q, k, v,
    )
    return vjp(ct)


flash_attention_op.defvjp(_fa_fwd, _fa_bwd)
