"""Pallas TPU kernel: flash-attention forward (fused online softmax).

The dominant roofline term for every dense train/prefill cell is the
unfused attention pipeline: XLA materialises f32 score/probability tensors
in HBM several times per layer (EXPERIMENTS.md SSRoofline).  This kernel
keeps the (TQ, TK) score tile and the online-softmax state (m, l, acc) in
VMEM across the KV grid steps, so HBM traffic collapses to q/k/v/out.

Layout: grid (B*Hkv, Sq/TQ, Skv/TK) — KV tiles innermost (sequential),
carrying (acc, m, l) in VMEM scratch; GQA handled by folding the q-head
group into the q tile row dimension.  Causal/window masking is computed
from iota against the absolute tile offsets, and fully-masked tiles are
skipped via ``pl.when`` (the causal-wedge skip gives the 2x).

Forward-only (serving/prefill use it directly; training wraps it in
``jax.custom_vjp`` with the chunked-jnp backward — see ops.py note).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

_NEG = -2.3819763e38


def _flash_fwd_kernel(
    q_ref,      # (1, TQ*G, D)   queries (g folded into rows)
    k_ref,      # (1, TK, D)
    v_ref,      # (1, TK, D)
    o_ref,      # (1, TQ*G, D)
    acc_ref,    # (TQ*G, D) f32 scratch
    m_ref,      # (TQ*G, 1) f32 scratch
    l_ref,      # (TQ*G, 1) f32 scratch
    *,
    tq: int,
    tk: int,
    g: int,
    scale: float,
    causal: bool,
    window: int | None,
    score_cap: float | None,
    n_k: int,
    sq_total: int,
    skv_total: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = qi * tq                      # absolute first query position
    k0 = ki * tk

    # skip tiles that are entirely masked by causality / window
    run = None
    if causal:
        run = k0 <= q0 + tq - 1       # some key <= some query
    if window is not None:
        w_ok = k0 + tk - 1 >= q0 - (window - 1)
        run = w_ok if run is None else jnp.logical_and(run, w_ok)
    if run is None:
        run = jnp.bool_(True)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale          # (TQ*G, D)
        k = k_ref[0].astype(jnp.float32)                  # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (TQ*G, TK)
        if score_cap is not None:
            s = score_cap * jnp.tanh(s / score_cap)
        rows = lax.broadcasted_iota(jnp.int32, (tq * g, tk), 0) // g + q0
        cols = lax.broadcasted_iota(jnp.int32, (tq * g, tk), 1) + k0
        ok = cols < skv_total
        dp = rows - cols
        if causal:
            ok = jnp.logical_and(ok, dp >= 0)
        if window is not None:
            ok = jnp.logical_and(ok, dp < window)
        s = jnp.where(ok, s, _NEG)
        m_prev = m_ref[...]                                # (TQ*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (TQ*G, TK)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "score_cap", "tile_q", "tile_k",
                     "interpret"),
)
def flash_attention_pallas(
    q: Array,           # (B, Sq, Hq, D)
    k: Array,           # (B, Skv, Hkv, D)
    v: Array,           # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    score_cap: float | None = None,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> Array:
    """Fused attention forward.  Returns (B, Sq, Hq, D).

    Positions are implicit (q row i attends kv rows <= i); ragged caches
    should mask via Skv truncation before the call.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5
    tile_q = min(tile_q, Sq)
    tile_k = min(tile_k, Skv)
    pq, pk = (-Sq) % tile_q, (-Skv) % tile_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sqp, Skp = Sq + pq, Skv + pk
    # fold: (B, Sq, Hkv, g, D) -> (B*Hkv, Sq*g, D) rows grouped by query
    qf = q.reshape(B, Sqp, Hkv, g, D).transpose(0, 2, 1, 3, 4)
    qf = qf.reshape(B * Hkv, Sqp * g, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skp, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skp, D)
    n_k = Skp // tile_k

    out = pl.pallas_call(
        functools.partial(
            _flash_fwd_kernel,
            tq=tile_q, tk=tile_k, g=g, scale=scale, causal=causal,
            window=window, score_cap=score_cap, n_k=n_k,
            sq_total=Sq, skv_total=Skv,
        ),
        grid=(B * Hkv, Sqp // tile_q, n_k),
        in_specs=[
            pl.BlockSpec((1, tile_q * g, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tile_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tile_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q * g, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, Sqp * g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q * g, D), jnp.float32),
            pltpu.VMEM((tile_q * g, 1), jnp.float32),
            pltpu.VMEM((tile_q * g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, Hkv, Sqp, g, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Sqp, Hq, D)[:, :Sq]
