"""Pallas TPU kernel: batched LB_KEOGH blocks (paper Eq. 7).

Computes the ``(Q, C)`` matrix of Keogh bounds between a tile of queries and
a tile of candidate envelopes.  This is the cascade's O(L) tier and the
workhorse the paper's Fig. 1 timings are dominated by.

Layout: grid ``(Q/TQ, C/TC)``; each program holds ``q`` ``(TQ, L)`` and the
envelope blocks ``(TC, L)`` in VMEM and loops over the TQ query rows,
emitting one ``(TC,)`` row of bounds per iteration.  The inner body is pure
clamped-difference VPU math (branch-free version of the paper's
``if A_i > U_i``).  The workload has no inner product structure, so the MXU
is idle by construction — this tier is VPU/VMEM-bandwidth-bound, which the
roofline analysis in EXPERIMENTS.md quantifies.

VMEM: (TQ + 2*TC + TQ*TC/L) rows of L f32. TQ=8, TC=128, L=4096 -> ~4.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array


def _lb_keogh_kernel(q_ref, u_ref, l_ref, out_ref):
    u = u_ref[...]            # (TC, L)
    lo = l_ref[...]           # (TC, L)
    tq = q_ref.shape[0]

    def row(i, _):
        qi = q_ref[i, :][None, :]                       # (1, L)
        over = jnp.maximum(qi - u, 0.0)
        under = jnp.maximum(lo - qi, 0.0)
        out_ref[i, :] = jnp.sum(over * over + under * under, axis=-1)
        return 0

    lax.fori_loop(0, tq, row, 0, unroll=True)


@functools.partial(
    jax.jit, static_argnames=("tile_q", "tile_c", "interpret")
)
def lb_keogh_pallas(
    q: Array,
    u: Array,
    lo: Array,
    *,
    tile_q: int = 8,
    tile_c: int = 128,
    interpret: bool = False,
) -> Array:
    """``(Q, L) x (C, L) envelopes -> (Q, C)`` LB_KEOGH matrix."""
    Q, L = q.shape
    C, _ = u.shape
    tile_q = min(tile_q, Q)
    tile_c = min(tile_c, C)
    pq, pc = (-Q) % tile_q, (-C) % tile_c
    if pq:
        q = jnp.pad(q, ((0, pq), (0, 0)))
    if pc:
        # pad candidates with an infinitely-wide envelope -> bound 0
        u = jnp.pad(u, ((0, pc), (0, 0)), constant_values=jnp.inf)
        lo = jnp.pad(lo, ((0, pc), (0, 0)), constant_values=-jnp.inf)
    Qp, Cp = Q + pq, C + pc
    out = pl.pallas_call(
        _lb_keogh_kernel,
        grid=(Qp // tile_q, Cp // tile_c),
        in_specs=[
            pl.BlockSpec((tile_q, L), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_c, L), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Cp), q.dtype),
        interpret=interpret,
    )(q, u, lo)
    return out[:Q, :C]
