"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Design (DESIGN.md SS6): GSPMD handles every dense layer well, but MoE
dispatch (data-dependent sort/scatter) is exactly where auto-partitioning
produces surprise all-gathers — so the routed path is an explicit
``shard_map`` island inside the jitted model:

  * tokens stay on their (pod, data) shard and are *replicated* across the
    ``model`` axis (they already are, activation-wise, at this point);
  * each model rank owns ``E / model_size`` experts and processes the
    capacity-limited slice of local tokens routed to them (sort-based,
    GShard-style position-in-expert capacity with drop);
  * partial outputs are combined with one ``psum_scatter`` over ``model``
    — the same wire cost as the row-parallel all-reduce a dense FFN of the
    active width would pay, which is why EP here adds no collective-term
    regression over the dense baseline (SSRoofline).

Shared experts (qwen2-moe / deepseek-moe) are a plain dense MLP handled by
GSPMD tensor parallelism outside the island.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import activation, mlp_apply, mlp_init

Array = jax.Array


def moe_init(
    rng,
    d_model: int,
    d_expert: int,
    n_experts_padded: int,
    n_shared: int,
    act: str,
) -> dict[str, Array]:
    """Params sized for the *padded* expert count (EP divisibility)."""
    ki = jax.nn.initializers.lecun_normal()
    ks = jax.random.split(rng, 5)
    ep = n_experts_padded
    p = {
        "router": ki(ks[0], (d_model, ep), jnp.float32),
        "wi": ki(ks[1], (ep, d_model, d_expert), jnp.float32),
        "wg": ki(ks[2], (ep, d_model, d_expert), jnp.float32),
        "wo": ki(ks[3], (ep, d_expert, d_model), jnp.float32),
    }
    if n_shared:
        p["shared"] = mlp_init(ks[4], d_model, n_shared * d_expert, act)
    return p


def _routed_local(
    xt: Array,            # (T, d) local tokens
    router: Array,        # (d, E_padded)
    wi: Array,            # (El, d, f) local experts
    wg: Array,
    wo: Array,
    *,
    top_k: int,
    n_real: int,          # real expert count (router is padded to E_padded)
    capacity_factor: float,
    act: str,
    ep_axis: str,
) -> tuple[Array, Array]:
    """Per-device routed-expert computation (runs inside shard_map)."""
    T, d = xt.shape
    E = router.shape[1]
    El = wi.shape[0]
    rank = lax.axis_index(ep_axis)
    e0 = rank * El

    logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)   # (T, E)
    if n_real < E:   # mask padding experts (clean EP divisibility)
        pad_mask = jnp.arange(E) >= n_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, top_k)                              # (T, k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(-1)                                    # (T*k,)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_ids)
    sids = flat_ids[order]
    pos = jnp.arange(T * top_k) - jnp.searchsorted(sids, sids, side="left")
    cap = int(math.ceil(T * top_k / n_real * capacity_factor))
    local = (sids >= e0) & (sids < e0 + El) & (pos < cap)
    dest = jnp.where(local, (sids - e0) * cap + pos, El * cap)    # drop row
    src_tok = order // top_k

    buf = jnp.zeros((El * cap + 1, d), xt.dtype)
    buf = buf.at[dest].set(xt[src_tok], mode="drop")
    eb = buf[: El * cap].reshape(El, cap, d)
    h = jnp.einsum("ecd,edf->ecf", eb, wi.astype(xt.dtype))
    g = jnp.einsum("ecd,edf->ecf", eb, wg.astype(xt.dtype))
    h = activation(act)(g) * h
    out = jnp.einsum("ecf,efd->ecd", h, wo.astype(xt.dtype))
    out_flat = out.reshape(El * cap, d)

    contrib = out_flat[jnp.minimum(dest, El * cap - 1)]
    contrib = contrib * (flat_w[order] * local)[:, None].astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype).at[src_tok, :].add(contrib)
    # combine partial expert outputs across the EP axis
    y = lax.psum(y, ep_axis)

    # aux losses (identical math on every EP rank): load balance + z-loss
    me = jnp.mean(probs, axis=0)                                  # (E,)
    one_hot_top1 = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = n_real * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jax.nn.logsumexp(logits, -1) ** 2
    )
    return y, aux


def moe_apply(
    p: dict[str, Array],
    x: Array,                      # (B, S, d)
    *,
    top_k: int,
    n_real: int,
    act: str,
    mesh: Mesh | None,
    capacity_factor: float = 1.25,
    ep_axis: str = "model",
    dp_axes: tuple[str, ...] = ("data",),
    ctx=None,
) -> tuple[Array, Array]:
    """MoE FFN: shared experts (dense TP) + routed experts (shard_map EP).

    Returns (output, aux_loss).  ``mesh`` may be None for unsharded unit
    tests, in which case the routed path runs on a trivial local "mesh" of
    the current device.
    """
    B, S, d = x.shape

    routed = functools.partial(
        _routed_local,
        top_k=top_k,
        n_real=n_real,
        capacity_factor=capacity_factor,
        act=act,
        ep_axis=ep_axis,
    )

    if mesh is None:
        import numpy as np
        devs = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, (dp_axes[0] if dp_axes else "data", ep_axis))

    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    # decode batches (B*S == 1) cannot shard over the data axes: fall back
    # to replicated tokens inside the island (EP still splits the experts)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if (B * S) % max(dp_size, 1):
        dp = ()

    def island(xt, r, wi, wg, wo):
        y, aux = routed(xt, r, wi, wg, wo)
        if dp:
            aux = lax.pmean(aux, dp)   # make the scalar mesh-uniform
        return y, aux

    from repro.distributed.sharding import shard_map_compat
    fn = shard_map_compat(
        island,
        mesh=mesh,
        in_specs=(
            P(dp, None),          # tokens: sharded over data axes
            P(None, None),        # router replicated
            P(ep_axis, None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
        ),
        out_specs=(P(dp, None), P()),
    )
    xt = x.reshape(B * S, d)
    y, aux = fn(xt, p["router"], p["wi"], p["wg"], p["wo"])
    y = y.reshape(B, S, d)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, act, ctx=ctx)
    return y, aux
