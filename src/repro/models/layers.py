"""Shared neural layers: norms, rotary embeddings (incl. M-RoPE), MLPs.

Conventions:
  * params are plain nested dicts of jnp arrays (fp32 at rest);
  * compute runs in bf16 (``cfg.compute_dtype``), losses in fp32;
  * all shapes are ``(batch, seq, ...)``; heads axes are explicit;
  * sharding is applied by the caller via constraint helpers in
    repro.distributed.sharding — layers stay mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: Array, cap: float | None) -> Array:
    """Gemma-2 style logit soft-capping: ``cap * tanh(x / cap)``."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> Array:
    """(d_head/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """Rotary embedding.

    Args:
      x: (B, S, H, D) queries or keys.
      positions: (B, S) integer positions.
    """
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array,
    positions: Array,
    sections: tuple[int, ...],
    theta: float = 10000.0,
) -> Array:
    """Multimodal rotary embedding (Qwen2-VL SS3): the head dim's frequency
    bands are split into (temporal, height, width) sections, each rotated by
    its own position stream.

    Args:
      x: (B, S, H, D).
      positions: (B, 3, S) integer positions (t, h, w); text tokens carry
        t == h == w so M-RoPE degrades to 1-D RoPE for them.
      sections: frequency-band split of D/2, summing to D/2 (e.g. 16/24/24
        for D=128).
    """
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    ang_tri = positions[..., None].astype(jnp.float32) * freqs  # (B, 3, S, D/2)
    parts = []
    start = 0
    for k, sec in enumerate(sections):
        parts.append(ang_tri[:, k, :, start : start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                       # (B, S, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / gated MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, act: str) -> dict[str, Array]:
    ki = jax.nn.initializers.lecun_normal()
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "wi": ki(k1, (d_model, d_ff), jnp.float32),
        "wo": ki(k3, (d_ff, d_model), jnp.float32),
    }
    if act == "silu":  # gated (SwiGLU-style)
        p["wg"] = ki(k2, (d_model, d_ff), jnp.float32)
    return p


def mlp_apply(p: dict[str, Array], x: Array, act: str, ctx=None) -> Array:
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if ctx is not None:
        h = ctx.con(h, "dp", None, "tp")
    if "wg" in p:
        h = activation(act)(x @ p["wg"].astype(dt)) * h
    else:
        h = activation(act)(h)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / output head
# ---------------------------------------------------------------------------

def embed_init(rng, vocab: int, d_model: int) -> Array:
    return jax.nn.initializers.normal(0.02)(rng, (vocab, d_model), jnp.float32)


def embed_lookup(table: Array, ids: Array, dtype) -> Array:
    return jnp.take(table, ids, axis=0).astype(dtype)


def chunked_cross_entropy(
    x: Array,
    w_head: Array,
    labels: Array,
    *,
    chunk: int = 512,
    final_softcap_val: float | None = None,
    mask: Array | None = None,
    unroll: bool = False,
    ctx=None,
) -> Array:
    """Mean next-token cross-entropy without materialising (B, S, V) fp32.

    Scans over sequence chunks: peak memory is (B, chunk, V) instead of
    (B, S, V) — the difference between fitting and OOMing for the 150k/256k
    vocab archs at seq 4k (DESIGN.md SS6).
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else (
            jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad)))
        )
    elif mask is None:
        mask = jnp.ones((B, S), bool)
    Sp = x.shape[1]
    n_chunks = Sp // chunk
    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        xi, li, mi = xs
        logits = (xi @ w_head.astype(xi.dtype)).astype(jnp.float32)
        if ctx is not None:
            logits = ctx.con(logits, "dp", None, "tp")
        if final_softcap_val is not None:
            logits = softcap(logits, final_softcap_val)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = jnp.where(mi, lse - gold, 0.0)
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mi)), None

    # checkpoint: recompute each chunk's logits in backward — otherwise the
    # scan stashes (B, chunk, V) softmax residuals for *every* chunk and the
    # chunking saves nothing for training.
    if unroll:   # cost-probe mode: identical math, while-free HLO
        carry = (0.0, 0.0)
        for i in range(n_chunks):
            carry, _ = body(carry, (xc[i], lc[i], mc[i]))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(
            jax.checkpoint(body), (0.0, 0.0), (xc, lc, mc)
        )
    return tot / jnp.maximum(cnt, 1.0)
