"""Sequence-model substrate: layers, attention, Mamba, MoE, assembled LM."""

from repro.models.model import LM

__all__ = ["LM"]
