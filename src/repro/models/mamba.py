"""Mamba-1 selective SSM block (falcon-mamba / jamba mixer).

TPU adaptation notes (DESIGN.md SS3/SS6):
  * the selective scan runs as a *chunked* linear recurrence: ``lax.scan``
    over sequence chunks carrying the (B, d_inner, n) state, with a
    log-depth ``lax.associative_scan`` inside each chunk.  Peak memory is
    O(B * chunk * d_inner * n) instead of O(B * S * d_inner * n) — the
    difference between ~1 GB and ~17 GB per device for falcon-mamba at
    seq 4k (see SSRoofline);
  * every SSM op is elementwise over ``d_inner``, so sharding d_inner over
    the ``model`` axis costs *zero* collectives inside the recurrence; the
    only cross-shard reductions are the tiny x_proj contraction and the
    out_proj row-parallel all-reduce;
  * the depthwise causal conv is four shifted adds (no conv primitive),
    which keeps the scanned-block HLO minimal and trivially shardable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def mamba_init(
    rng,
    d_model: int,
    d_inner: int,
    d_state: int,
    dt_rank: int,
    conv_width: int = 4,
) -> dict[str, Array]:
    ki = jax.nn.initializers.lecun_normal()
    ks = jax.random.split(rng, 6)
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))
    return {
        "in_proj": ki(ks[0], (d_model, 2 * d_inner), jnp.float32),
        "conv_w": jax.nn.initializers.normal(0.1)(
            ks[1], (conv_width, d_inner), jnp.float32
        ),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": ki(ks[2], (d_inner, dt_rank + 2 * d_state), jnp.float32),
        "dt_proj": ki(ks[3], (dt_rank, d_inner), jnp.float32),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": ki(ks[5], (d_inner, d_model), jnp.float32),
    }


def _causal_conv(x: Array, w: Array, b: Array, prev: Array | None) -> Array:
    """Depthwise causal conv as shifted adds.  x: (B, S, C), w: (K, C).

    ``prev`` is the (B, K-1, C) tail of the previous segment (decode cache);
    zeros when starting from scratch.
    """
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)           # (B, S+K-1, C)
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + S, :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _chunked_selective_scan(
    delta: Array,       # (B, S, C) f32 — softplus'd step sizes
    u: Array,           # (B, S, C) f32 — conv+silu activations
    A: Array,           # (C, N) f32 — negative-definite state matrix
    Bmat: Array,        # (B, S, N) f32
    Cmat: Array,        # (B, S, N) f32
    h0: Array,          # (B, C, N) f32
    chunk: int,
    *,
    unroll: bool = False,
    scan_dtype=jnp.float32,
) -> tuple[Array, Array]:
    """Linear recurrence h_t = exp(delta_t A) h_{t-1} + delta_t u_t B_t.

    The (B, chunk, C, N) discretised tensors are materialised *inside* the
    chunk body (and the body is checkpointed), so peak memory is one
    chunk's worth — O(B * chunk * C * N) — regardless of S.

    ``scan_dtype=bfloat16`` halves the associative-scan level traffic
    (SSPerf hillclimb): the decay factors live in (0, 1] and the carried
    state is re-accumulated in f32 at chunk boundaries, so the precision
    loss is bounded per chunk (validated vs the f32 oracle in tests).

    Returns (y (B, S, C) f32 where y_t = <h_t, C_t>, final state h).
    """
    B, S, C = delta.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # identity steps: delta = 0 -> a = exp(0) = 1, b = 0
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    dl = delta.reshape(B, nc, chunk, C).swapaxes(0, 1)
    uc = u.reshape(B, nc, chunk, C).swapaxes(0, 1)
    Bm = Bmat.reshape(B, nc, chunk, N).swapaxes(0, 1)
    Cm = Cmat.reshape(B, nc, chunk, N).swapaxes(0, 1)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    def body(h, xs):
        d, uu, bm, cm = xs                             # (B, chunk, C) / (B, chunk, N)
        a = jnp.exp(d[..., None] * A).astype(scan_dtype)   # (B, chunk, C, N)
        b = ((d * uu)[..., None] * bm[:, :, None, :]).astype(scan_dtype)
        a_pre, b_pre = lax.associative_scan(combine, (a, b), axis=1)
        h_t = (
            a_pre.astype(jnp.float32) * h[:, None]
            + b_pre.astype(jnp.float32)
        )                                              # (B, chunk, C, N) f32
        y = jnp.einsum("btcn,btn->btc", h_t, cm)
        return h_t[:, -1], y

    if unroll:   # cost-probe mode: identical math, while-free HLO
        h = h0
        ys = []
        for i in range(nc):
            h, y = body(h, (dl[i], uc[i], Bm[i], Cm[i]))
            ys.append(y)
        ys = jnp.stack(ys)
    else:
        h, ys = lax.scan(jax.checkpoint(body), h0, (dl, uc, Bm, Cm))
    y = ys.swapaxes(0, 1).reshape(B, Sp, C)
    return y[:, :S], h


def mamba_apply(
    p: dict[str, Array],
    x: Array,                      # (B, S, d_model)
    *,
    d_state: int,
    conv_width: int = 4,
    chunk: int = 256,
    cache: dict[str, Array] | None = None,
    unroll: bool = False,
    scan_dtype=jnp.float32,
    impl: str = "scan",    # "scan" | "pallas" | "bypass" (cost probes only)
    ctx=None,
) -> tuple[Array, dict[str, Array] | None]:
    """Mamba-1 mixer.  With ``cache`` (dict h/conv) runs as an incremental
    segment (decode); returns updated cache.

    ``impl="pallas"`` routes the recurrence through the fused Pallas scan
    (kernels/mamba_scan.py): HBM traffic = inputs+outputs only.
    ``impl="bypass"`` replaces the recurrence with a shape-compatible
    elementwise stand-in — used by the dry-run cost probes to isolate the
    scan's HLO cost (never for real computation)."""
    B, S, _ = x.shape
    dt = x.dtype
    d_inner = p["out_proj"].shape[0]
    dt_rank = p["dt_proj"].shape[0]

    xz = x @ p["in_proj"].astype(dt)                  # (B, S, 2*din)
    if ctx is not None:   # d_inner channels over tp: zero-collective scan
        xz = ctx.con(xz, "dp", None, "tp")
    xi, z = jnp.split(xz, 2, axis=-1)
    prev = cache["conv"] if cache is not None else None
    u = _causal_conv(xi, p["conv_w"], p["conv_b"], prev)
    u = jax.nn.silu(u)

    proj = u @ p["x_proj"].astype(dt)                 # (B, S, dtr + 2n)
    dt_raw = proj[..., :dt_rank]
    Bmat = proj[..., dt_rank : dt_rank + d_state].astype(jnp.float32)
    Cmat = proj[..., dt_rank + d_state :].astype(jnp.float32)
    delta = jax.nn.softplus(
        (dt_raw @ p["dt_proj"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"]
    )                                                  # (B, S, din) f32
    A = -jnp.exp(p["A_log"])                           # (din, n)
    uf = u.astype(jnp.float32)

    h0 = (
        cache["h"]
        if cache is not None
        else jnp.zeros((B, d_inner, d_state), jnp.float32)
    )
    if impl == "pallas":
        from repro.kernels.ops import mamba_scan_op

        y, h = mamba_scan_op(delta, uf, A, Bmat, Cmat, h0)
    elif impl == "bypass":
        # cost-probe stand-in: correct shapes/dtypes, no recurrence
        y = delta * uf * jnp.sum(Bmat * Cmat, -1, keepdims=True)
        h = h0 + jnp.einsum("bsc,bsn->bcn", delta * uf, Bmat) * 0.0
    else:
        y, h = _chunked_selective_scan(
            delta, uf, A, Bmat, Cmat, h0, chunk,
            unroll=unroll, scan_dtype=scan_dtype,
        )
    y = y + uf * p["D"]
    y = (y.astype(dt)) * jax.nn.silu(z)
    if ctx is not None:
        y = ctx.con(y, "dp", None, "tp")
    out = y @ p["out_proj"].astype(dt)
    if ctx is not None:
        out = ctx.con(out, "dp", None, None)

    new_cache = None
    if cache is not None:
        tail = jnp.concatenate([cache["conv"], xi], axis=1)[:, -(conv_width - 1):]
        new_cache = {"h": h, "conv": tail}
    return out, new_cache


def init_mamba_cache(
    batch: int, d_inner: int, d_state: int, conv_width: int = 4,
    dtype=jnp.bfloat16,
) -> dict[str, Array]:
    return {
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
    }
