"""Top-level language/sequence model: scanned block stack + heads.

Supports every assigned architecture family (dense / MoE / SSM / hybrid /
encoder-audio / VLM) from a single implementation, selected by
``ArchConfig``.  Layers are grouped into the arch's periodic pattern and
scanned over repeats (with optional remat), so the lowered HLO is compact
regardless of depth — a requirement for compiling 40 dry-run cells.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardCtx
from repro.models.blocks import init_layer_cache, layer_apply, layer_init
from repro.models.layers import chunked_cross_entropy, embed_init, rms_norm, softcap

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ArchConfig
    mesh: Mesh | None = None
    dp_axes: tuple[str, ...] = ("data",)
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    kv_chunk: int = 1024
    mamba_chunk: int = 256
    ce_chunk: int = 512
    cache_dtype: Any = jnp.bfloat16
    scan_layers: bool = True     # False: unroll the layer stack
    unroll_scans: bool = False   # unroll inner kv/ce/mamba scans (cost probe)
    mamba_scan_dtype: Any = None  # None -> f32; bf16 is a SSPerf lever
    ssm_impl: str = "scan"       # "scan" | "pallas" | "bypass" (SSPerf)
    attn_impl: str = "chunked"   # "chunked" | "pallas" | "bypass" (SSPerf)
    seq_shard: bool = False      # Megatron-SP residual stream (SSPerf)

    @property
    def ctx(self) -> ShardCtx | None:
        if self.mesh is None:
            return None
        return ShardCtx(
            mesh=self.mesh, dp=self.dp_axes, tp="model",
            seq_shard=self.seq_shard,
        )

    def compute_params(self, params: dict[str, Any]) -> dict[str, Any]:
        """Cast >=2-D fp32 params to the compute dtype once per step: every
        downstream FSDP all-gather and matmul temp is then bf16 (half the
        wire bytes and half the temp HBM), while 1-D norm scales and the
        at-rest/optimizer copies stay fp32."""
        def cast(a):
            if a.ndim >= 2 and a.dtype == jnp.float32:
                return a.astype(self.compute_dtype)
            return a
        return jax.tree.map(cast, params)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, rng) -> dict[str, Any]:
        cfg = self.cfg
        prelude, period, n_repeat = cfg.layout()
        k_embed, k_pre, k_scan, k_head = jax.random.split(rng, 4)
        params: dict[str, Any] = {
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if cfg.embed_inputs:
            params["embed"] = embed_init(k_embed, cfg.vocab, cfg.d_model)
        else:
            params["in_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if not cfg.tie_embeddings or not cfg.embed_inputs:
            ki = jax.nn.initializers.lecun_normal()
            params["head"] = ki(k_head, (cfg.d_model, cfg.vocab), jnp.float32)
        params["prelude"] = [
            layer_init(k, cfg, spec)
            for k, spec in zip(jax.random.split(k_pre, max(len(prelude), 1)), prelude)
        ]
        params["scan"] = []
        for pos, spec in enumerate(period):
            keys = jax.random.split(jax.random.fold_in(k_scan, pos), n_repeat)
            stacked = jax.vmap(lambda kk: layer_init(kk, cfg, spec))(keys)
            params["scan"].append(stacked)
        return params

    # ------------------------------------------------------------------
    # backbone
    # ------------------------------------------------------------------
    def backbone(
        self,
        params: dict[str, Any],
        x: Array,                 # (B, S, d) compute-dtype activations
        positions: Array,
        caches: dict[str, Any] | None = None,
        cache_index: Array | None = None,
    ) -> tuple[Array, dict[str, Any] | None, Array]:
        cfg = self.cfg
        prelude, period, n_repeat = cfg.layout()
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: dict[str, Any] = {"prelude": [], "scan": None}

        apply = functools.partial(
            layer_apply, mesh=self.mesh, dp_axes=self.dp_axes,
            cache_index=cache_index, kv_chunk=self.kv_chunk,
            mamba_chunk=self.mamba_chunk, unroll=self.unroll_scans,
            mamba_scan_dtype=self.mamba_scan_dtype,
            ssm_impl=self.ssm_impl, attn_impl=self.attn_impl, ctx=self.ctx,
        )

        for i, spec in enumerate(prelude):
            c = caches["prelude"][i] if caches is not None else None
            x, nc, aux = apply(cfg, spec, params["prelude"][i], x, positions, cache=c)
            aux_total = aux_total + aux
            new_caches["prelude"].append(nc)

        def body(carry, xs):
            h = carry
            p_slices, c_slices = xs
            aux = jnp.zeros((), jnp.float32)
            ncs = []
            for pos, spec in enumerate(period):
                c = c_slices[pos] if c_slices is not None else None
                h, nc, a = apply(cfg, spec, p_slices[pos], h, positions, cache=c)
                aux = aux + a
                ncs.append(nc)
            return h, (ncs if caches is not None else 0, aux)

        body_fn = jax.checkpoint(body) if (self.remat and caches is None) else body
        scan_caches = caches["scan"] if caches is not None else None
        xs = (params["scan"], scan_caches)
        if scan_caches is None:
            # replace None with per-step dummy so scan sees a valid pytree
            xs = (params["scan"], [None] * len(period))
        if self.scan_layers:
            x, (scan_ncs, auxs) = lax.scan(body_fn, x, xs)
            aux_total = aux_total + jnp.sum(auxs)
        else:
            # unrolled path (cost-probe mode): same math, no while loops
            ncs_steps, aux_sum = [], jnp.zeros((), jnp.float32)
            for step_i in range(n_repeat):
                xs_i = jax.tree.map(lambda a: a[step_i], xs)
                x, (ncs_i, aux_i) = body(x, xs_i)
                ncs_steps.append(ncs_i)
                aux_sum = aux_sum + aux_i
            aux_total = aux_total + aux_sum
            scan_ncs = (
                jax.tree.map(lambda *ls: jnp.stack(ls), *ncs_steps)
                if caches is not None
                else 0
            )
        new_caches["scan"] = scan_ncs if caches is not None else None
        return x, (new_caches if caches is not None else None), aux_total

    # ------------------------------------------------------------------
    # inputs -> activations
    # ------------------------------------------------------------------
    def embed(self, params: dict[str, Any], batch: dict[str, Array]) -> Array:
        cfg = self.cfg
        dt = self.compute_dtype
        if not cfg.embed_inputs:
            x = batch["frames"].astype(dt)          # audio frontend stub
            return rms_norm(x, params["in_norm"], cfg.norm_eps)
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        if cfg.vision_prefix and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(dt)  # (B, P, d) patch stub
            x = lax.dynamic_update_slice(x, ve, (0, 0, 0))
        if self.ctx is not None:
            x = self.ctx.con(x, "dp", "sp", None)
        return x

    def positions_for(self, batch: dict[str, Array], seq: int) -> Array:
        if "positions" in batch:
            return batch["positions"]
        b = next(iter(batch.values())).shape[0]
        return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (b, seq))

    def head(self, params: dict[str, Any]) -> Array:
        if "head" in params:
            return params["head"]
        return params["embed"].T

    # ------------------------------------------------------------------
    # steps
    # ------------------------------------------------------------------
    def loss_fn(
        self, params: dict[str, Any], batch: dict[str, Array]
    ) -> tuple[Array, dict[str, Array]]:
        cfg = self.cfg
        params = self.compute_params(params)
        x = self.embed(params, batch)
        positions = self.positions_for(batch, x.shape[1])
        hidden, _, aux = self.backbone(params, x, positions)
        hidden = rms_norm(hidden, params["final_norm"], cfg.norm_eps)
        labels = batch["labels"]
        ce = chunked_cross_entropy(
            hidden, self.head(params), jnp.maximum(labels, 0),
            chunk=self.ce_chunk,
            final_softcap_val=cfg.final_softcap, mask=labels >= 0,
            unroll=self.unroll_scans, ctx=self.ctx,
        )
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill(
        self, params: dict[str, Any], batch: dict[str, Array],
        max_len: int | None = None,
    ) -> tuple[Array, dict[str, Any], Array]:
        """Forward + KV/SSM-state fill.  Returns (last-token logits, caches,
        next cache index).  ``max_len`` sizes the cache for continued
        decoding (defaults to the prompt length)."""
        cfg = self.cfg
        params = self.compute_params(params)
        x = self.embed(params, batch)
        B, S, _ = x.shape
        positions = self.positions_for(batch, S)
        caches = self.init_caches(B, max_len or S)
        hidden, caches, _ = self.backbone(
            params, x, positions, caches, jnp.int32(0)
        )
        hidden = rms_norm(hidden[:, -1:, :], params["final_norm"], cfg.norm_eps)
        logits = (hidden @ self.head(params).astype(hidden.dtype)).astype(jnp.float32)
        logits = softcap(logits[:, 0, :], cfg.final_softcap)
        return logits, caches, jnp.int32(S)

    def decode_step(
        self,
        params: dict[str, Any],
        caches: dict[str, Any],
        tokens: Array,               # (B, 1) int32 (or (B, 1, d) frames)
        cache_index: Array,          # scalar int32: write position
    ) -> tuple[Array, dict[str, Any]]:
        """One autoregressive step against a filled cache."""
        cfg = self.cfg
        params = self.compute_params(params)
        if cfg.embed_inputs:
            x = jnp.take(params["embed"], tokens, axis=0).astype(self.compute_dtype)
        else:
            raise ValueError("encoder-only architectures have no decode step")
        B = x.shape[0]
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(cache_index[None, None], (B, 3))[:, :, None]
        else:
            pos = jnp.broadcast_to(cache_index[None], (B,))[:, None]
        hidden, caches, _ = self.backbone(params, x, pos, caches, cache_index)
        hidden = rms_norm(hidden, params["final_norm"], cfg.norm_eps)
        logits = (hidden @ self.head(params).astype(hidden.dtype)).astype(jnp.float32)
        logits = softcap(logits[:, 0, :], cfg.final_softcap)
        return logits, caches

    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int) -> dict[str, Any]:
        cfg = self.cfg
        prelude, period, n_repeat = cfg.layout()
        pre = [
            init_layer_cache(cfg, spec, batch, max_len, self.cache_dtype)
            for spec in prelude
        ]
        scan = []
        for spec in period:
            one = init_layer_cache(cfg, spec, batch, max_len, self.cache_dtype)
            scan.append(
                jax.tree.map(
                    lambda a: jnp.zeros((n_repeat,) + a.shape, a.dtype), one
                )
            )
        return {"prelude": pre, "scan": scan}
