"""GQA attention with chunked (flash-style) softmax, sliding windows,
softcaps, RoPE/M-RoPE, and KV-cache decode.

The softmax is computed online over KV chunks (``lax.scan`` carrying the
running max / normaliser / accumulator), so peak memory is
O(B * H * Sq * kv_chunk) instead of O(B * H * Sq * Skv) — this is what makes
the 32k prefill and 512k-cache decode shapes lower without materialising
quadratic score tensors, and it keeps the scanned-layer HLO compact for the
multi-pod dry-run (DESIGN.md SS6).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_mrope, apply_rope, softcap

Array = jax.Array

_NEG = -2.3819763e38  # large negative for masking in f32


def attn_init(
    rng,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    *,
    qkv_bias: bool = False,
) -> dict[str, Array]:
    ki = jax.nn.initializers.lecun_normal()
    ks = jax.random.split(rng, 4)
    p = {
        "wq": ki(ks[0], (d_model, n_heads * d_head), jnp.float32),
        "wk": ki(ks[1], (d_model, n_kv_heads * d_head), jnp.float32),
        "wv": ki(ks[2], (d_model, n_kv_heads * d_head), jnp.float32),
        "wo": ki(ks[3], (n_heads * d_head, d_model), jnp.float32),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), jnp.float32)
    return p


def flash_attention(
    q: Array,           # (B, Sq, Hq, D)
    k: Array,           # (B, Skv, Hkv, D)
    v: Array,           # (B, Skv, Hkv, D)
    q_pos: Array,       # (B, Sq) int32
    kv_pos: Array,      # (B, Skv) int32
    *,
    causal: bool = True,
    window: int | None = None,
    score_cap: float | None = None,
    kv_valid: Array | None = None,   # (B, Skv) bool
    kv_chunk: int = 1024,
    unroll: bool = False,
) -> Array:
    """Online-softmax attention. Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, g, D)
    qf = qf.transpose(0, 2, 3, 1, 4)                  # (B, Hkv, g, Sq, D)

    kv_chunk = min(kv_chunk, Skv)
    pad = (-Skv) % kv_chunk
    if kv_valid is None:
        kv_valid = jnp.ones((B, Skv), bool)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
    n_chunks = (Skv + pad) // kv_chunk
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, D).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, D).transpose(1, 0, 3, 2, 4)
    pc = kv_pos.reshape(B, n_chunks, kv_chunk).swapaxes(0, 1)
    mc = kv_valid.reshape(B, n_chunks, kv_chunk).swapaxes(0, 1)

    def body(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i, valid_i = xs                   # (B, Hkv, C, D) etc.
        s = jnp.einsum(
            "bhgqd,bhcd->bhgqc", qf, k_i.astype(jnp.float32)
        )                                              # (B, Hkv, g, Sq, C)
        if score_cap is not None:
            s = softcap(s, score_cap)
        ok = valid_i[:, None, None, None, :]
        dp = q_pos[:, None, None, :, None] - p_i[:, None, None, None, :]
        if causal:
            ok = ok & (dp >= 0)
        if window is not None:
            ok = ok & (dp < window)
        s = jnp.where(ok, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, Hkv, g, Sq), _NEG, jnp.float32),
        jnp.zeros((B, Hkv, g, Sq), jnp.float32),
        jnp.zeros((B, Hkv, g, Sq, D), jnp.float32),
    )
    # checkpoint: recompute each chunk's score/softmax block in backward —
    # without it the scan saves the (B, Hkv, g, Sq, C) probability tensor
    # for every KV chunk, reintroducing the quadratic memory this chunked
    # formulation exists to avoid.
    if unroll:   # cost-probe mode: identical math, while-free HLO
        carry = init
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[i], vc[i], pc[i], mc[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(jax.checkpoint(body), init, (kc, vc, pc, mc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B, Hkv, g, Sq, D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def attn_apply(
    p: dict[str, Array],
    x: Array,                       # (B, S, d_model)
    positions: Array,               # (B, S) or (B, 3, S) for M-RoPE
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    causal: bool = True,
    window: int | None = None,
    score_cap: float | None = None,
    rope_theta: float = 10000.0,
    mrope_sections: tuple[int, ...] | None = None,
    cache: dict[str, Array] | None = None,
    cache_index: Array | None = None,
    kv_chunk: int = 1024,
    unroll: bool = False,
    impl: str = "chunked",   # "chunked" | "pallas" | "bypass" (probes only)
    ctx=None,
) -> tuple[Array, dict[str, Array] | None]:
    """Self-attention (train/prefill) or cached decode step.

    If ``cache`` is given, the current k/v are written at ``cache_index``
    and attention runs against the whole cache (unwritten slots masked).
    ``impl="pallas"`` routes self-attention (no cache) through the fused
    flash kernel; ``"bypass"`` is the dry-run cost-probe stand-in.
    Returns (output, updated cache or None).
    """
    B, S, _ = x.shape
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, n_heads, d_head)
    k = k.reshape(B, S, n_kv_heads, d_head)
    v = v.reshape(B, S, n_kv_heads, d_head)
    if ctx is not None:   # heads over tp (replicated when not divisible)
        q = ctx.con(q, "dp", None, "tp", None)
        k = ctx.con(k, "dp", None, "tp", None)
        v = ctx.con(v, "dp", None, "tp", None)

    if mrope_sections is not None:
        q = apply_mrope(q, positions, mrope_sections, rope_theta)
        k = apply_mrope(k, positions, mrope_sections, rope_theta)
        q_pos = positions[:, 0, :]
    else:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        q_pos = positions

    if cache is None:
        if impl == "pallas":
            from repro.kernels.ops import flash_attention_op

            out = flash_attention_op(q, k, v, causal, window, score_cap)
        elif impl == "bypass":
            # probe stand-in: correct shapes, no score computation
            g = n_heads // n_kv_heads
            out = jnp.repeat(v, g, axis=2) * (q_pos[..., None, None] * 0 + 1.0)
        else:
            out = flash_attention(
                q, k, v, q_pos, q_pos,
                causal=causal, window=window, score_cap=score_cap,
                kv_chunk=kv_chunk, unroll=unroll,
            )
        new_cache = None
    else:
        # decode/prefill: write this step's k/v, attend over the prefix
        Smax = cache["k"].shape[1]
        ck = lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0)
        )
        cv = lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0)
        )
        if S == Smax and impl in ("pallas", "bypass"):
            # full-cache prefill: attention over the cache == self-attention
            if impl == "pallas":
                from repro.kernels.ops import flash_attention_op

                out = flash_attention_op(q, k, v, causal, window, score_cap)
            else:
                g = n_heads // n_kv_heads
                out = jnp.repeat(v, g, axis=2) * (
                    q_pos[..., None, None] * 0 + 1.0
                )
        else:
            slot_pos = jnp.arange(Smax, dtype=jnp.int32)
            kv_valid = (slot_pos < cache_index + S)[None, :]
            kv_valid = jnp.broadcast_to(kv_valid, (B, Smax))
            kv_pos = jnp.broadcast_to(slot_pos[None, :], (B, Smax))
            out = flash_attention(
                q, ck.astype(dt), cv.astype(dt), q_pos, kv_pos,
                causal=causal, window=window, score_cap=score_cap,
                kv_valid=kv_valid, kv_chunk=kv_chunk, unroll=unroll,
            )
        new_cache = {"k": ck, "v": cv}

    out = out.reshape(B, S, n_heads * d_head)
    if ctx is not None:
        out = ctx.con(out, "dp", None, "tp")
    out = out @ p["wo"].astype(dt)
    if ctx is not None:
        out = ctx.con(out, "dp", None, None)
    return out, new_cache


def init_cache(
    batch: int,
    max_len: int,
    n_kv_heads: int,
    d_head: int,
    dtype=jnp.bfloat16,
) -> dict[str, Array]:
    shape = (batch, max_len, n_kv_heads, d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
