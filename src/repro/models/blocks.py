"""Transformer/Mamba block assembly driven by ``LayerSpec``.

A block = pre-norm mixer (attention or Mamba) + residual, then pre-norm FFN
(dense MLP or MoE) + residual.  The period structure from
``ArchConfig.layout()`` is static, so the scanned stack body in model.py
unrolls the (small) period and scans over repeats — the key to compact HLO
for 24-80 layer archs on the multi-pod dry-run.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.attention import attn_apply, attn_init
from repro.models.layers import mlp_apply, mlp_init, rms_norm
from repro.models.mamba import mamba_apply, mamba_init
from repro.models.moe import moe_apply, moe_init

Array = jax.Array


def layer_init(rng, cfg: ArchConfig, spec: LayerSpec) -> dict[str, Any]:
    ks = jax.random.split(rng, 3)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if spec.mixer == "attn":
        p["attn"] = attn_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias,
        )
    else:
        p["mamba"] = mamba_init(
            ks[0], cfg.d_model, cfg.d_inner_, cfg.ssm_state, cfg.dt_rank_,
            cfg.conv_width,
        )
    if spec.moe:
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["moe"] = moe_init(
            ks[1], cfg.d_model, cfg.d_expert or cfg.d_ff,
            cfg.n_experts_padded, cfg.n_shared_experts, cfg.act,
        )
    elif cfg.d_ff > 0:
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def layer_apply(
    cfg: ArchConfig,
    spec: LayerSpec,
    p: dict[str, Any],
    x: Array,
    positions: Array,
    *,
    mesh: Mesh | None,
    dp_axes: tuple[str, ...],
    cache: dict[str, Array] | None = None,
    cache_index: Array | None = None,
    kv_chunk: int = 1024,
    mamba_chunk: int = 256,
    unroll: bool = False,
    mamba_scan_dtype=None,
    ssm_impl: str = "scan",
    attn_impl: str = "chunked",
    ctx=None,
) -> tuple[Array, dict[str, Array] | None, Array]:
    """Apply one block. Returns (x, new_cache, aux_loss)."""
    import jax.numpy as _jnp
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        out, new_cache = attn_apply(
            p["attn"], h, positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.head_dim, causal=cfg.causal, window=spec.window,
            score_cap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
            mrope_sections=cfg.mrope_sections,
            cache=cache, cache_index=cache_index, kv_chunk=kv_chunk,
            unroll=unroll,
            impl=attn_impl if x.shape[1] > 1 else "chunked",
            ctx=ctx,
        )
    else:
        out, new_cache = mamba_apply(
            p["mamba"], h, d_state=cfg.ssm_state, conv_width=cfg.conv_width,
            chunk=mamba_chunk, cache=cache, unroll=unroll,
            scan_dtype=mamba_scan_dtype or _jnp.float32,
            impl=ssm_impl if x.shape[1] > 1 else "scan", ctx=ctx,
        )
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if spec.moe:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        out, aux = moe_apply(
            p["moe"], h, top_k=cfg.top_k, n_real=cfg.n_experts,
            act=cfg.act, mesh=mesh, dp_axes=dp_axes, ctx=ctx,
        )
        x = x + out
    elif cfg.d_ff > 0:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg.act, ctx=ctx)
    if ctx is not None:
        # "sp": with seq-sharded residuals (Megatron-SP) the f32 norm
        # intermediates shard over tp; no-op otherwise
        x = ctx.con(x, "dp", "sp", None)
    return x, new_cache, aux


def init_layer_cache(
    cfg: ArchConfig,
    spec: LayerSpec,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
) -> dict[str, Array]:
    """Decode-state for one layer (KV cache or SSM state)."""
    if spec.mixer == "attn":
        shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return {
        "h": jnp.zeros((batch, cfg.d_inner_, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner_), dtype),
    }
