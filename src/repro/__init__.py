"""repro — Elastic-band DTW lower bounds (LB_ENHANCED) at pod scale.

Pillar A (the paper): ``repro.core`` (bounds + DTW), ``repro.kernels``
(Pallas TPU kernels), ``repro.search`` (exact pruned NN-DTW engine).
Pillar B (substrate): ``repro.models``/``configs``/``train``/``serve``/
``distributed``/``launch`` — the ten assigned architectures under the
production (pod, data, model) mesh.  See DESIGN.md.
"""

__version__ = "1.0.0"
