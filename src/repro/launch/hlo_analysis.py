"""Roofline-term extraction from compiled SPMD artifacts.

``cost_analysis()`` supplies per-device HLO FLOPs and bytes; collective
wire bytes are *not* in cost_analysis, so we parse the optimised
(post-partitioning) HLO text and sum per-collective wire traffic with
ring-algorithm accounting:

  all-reduce        2 * bytes * (g-1)/g     (reduce-scatter + all-gather)
  all-gather        bytes * (g-1)/g         (bytes = gathered result)
  reduce-scatter    bytes_out * (g-1)       (bytes_out = local shard)
  all-to-all        bytes * (g-1)/g
  collective-permute bytes

Hardware constants (v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (given in the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [groups, group_size]
        return max(1, int(m.group(2)))
    return n_devices


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, b: float) -> None:
        self.wire_bytes += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.count += 1


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device collective wire bytes from optimised HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w-]+)", ls)
        if not m:
            continue
        result_type, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                kind = c
                break
        if kind is None:
            continue
        b = _shape_bytes(result_type)
        g = _group_size(ls, n_devices)
        if g <= 1:
            continue
        if kind == "all-reduce":
            wire = 2.0 * b * (g - 1) / g
        elif kind == "all-gather":
            wire = b * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = b * (g - 1)
        elif kind == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = float(b)
        stats.add(kind, wire)
    return stats


def roofline(
    cost: dict[str, Any],
    coll: CollectiveStats,
    *,
    model_flops: float,
    n_devices: int,
    ideal_bytes_per_device: float = 0.0,
) -> dict[str, Any]:
    """The three roofline terms (seconds, per device) + bottleneck.

    ``roofline_fraction`` = speed-of-light step time / bound step time,
    where speed-of-light = max(useful-FLOPs time, mandatory-bytes time).
    The mandatory-bytes floor matters for decode (param+cache reads bound
    the step no matter how good the kernels are).
    """
    flops = float(cost.get("flops", 0.0))
    mem_bytes = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    useful = model_flops / n_devices / PEAK_FLOPS if model_flops else 0.0
    ideal_mem_s = ideal_bytes_per_device / HBM_BW
    sol_s = max(useful, ideal_mem_s)
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": mem_bytes,
        "collective_bytes_per_device": coll.wire_bytes,
        "collective_by_kind": coll.by_kind,
        "n_collectives": coll.count,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_step_s": step_s,
        "model_flops": model_flops,
        "model_flops_per_device": model_flops / n_devices if model_flops else 0.0,
        "useful_compute_s": useful,
        "ideal_memory_s": ideal_mem_s,
        "speed_of_light_s": sol_s,
        "useful_flops_ratio": (model_flops / n_devices / flops) if flops and model_flops else 0.0,
        "roofline_fraction": sol_s / step_s if step_s else 0.0,
    }
