"""Generate EXPERIMENTS.md tables from results/dryrun JSONs.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
Prints markdown for SSDry-run and SSRoofline.
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def _fmt_b(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PB"


def load(result_dir: str, mesh: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(result_dir, f"{mesh}__*.json"))):
        out.append(json.load(open(p)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3, "search_1m": 4}
    out.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return out


def dryrun_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | status | compile s | args/dev | temps/dev | out/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP — {r['reason']} | | | | |"
            )
            continue
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{_fmt_b(m['argument_size_in_bytes'])} | "
            f"{_fmt_b(m['temp_size_in_bytes'])} | "
            f"{_fmt_b(m['output_size_in_bytes'])} |"
        )
    return "\n".join(lines)


def roofline_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful-FLOPs ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skip" or "roofline" not in r:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"**{rf['dominant']}** | {rf['useful_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.4f} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    single = load(args.dir, "single")
    multi = load(args.dir, "multi")
    print("### Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(single))
    if multi:
        print("\n### Dry-run — multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(multi))
    print("\n### Roofline (single pod)\n")
    print(roofline_table(single))


if __name__ == "__main__":
    main()
