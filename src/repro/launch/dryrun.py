import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (including
# jax and repro.*) — jax locks the device count at first initialisation.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the real step function (train_step / prefill /
decode_step / the paper's distributed search_step), attach the production
shardings to ShapeDtypeStruct stand-ins (no allocation), then

    jax.jit(step).lower(...).compile()

on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh of host
placeholder devices.  ``memory_analysis()`` proves per-device fit.

Roofline costs (SSRoofline methodology): XLA's ``cost_analysis()`` counts
every ``while`` body exactly once, so the scanned-layer lowering
undercounts FLOPs/bytes/collectives by the trip counts.  We therefore
measure costs on *unrolled probe lowerings* — 1-period and 2-period layer
stacks with all inner scans disabled (kv/ce/mamba chunk = full length) at
two sequence lengths — then fit per-period costs as a + q*S (decode:
a + c*S_cache) or a*S + q*S^2 (train/prefill) and extrapolate to the real
depth and length.  Train terms are multiplied by 4/3 for remat recompute.
Collective wire bytes are parsed from the probes' (while-free) HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --paper --mesh both
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicability
from repro.configs.registry import ARCHS, get_arch
from repro.distributed.sharding import (
    AxisRules,
    batch_specs,
    cache_shardings,
    param_shardings,
)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_state, make_train_step

Array = jax.Array

REMAT_FACTOR = 4.0 / 3.0   # one extra forward during backward

# SSPerf levers toggled via env for before/after measurement
MAMBA_SCAN_DTYPE = (
    jnp.bfloat16 if os.environ.get("REPRO_MAMBA_SCAN_BF16") == "1" else None
)
SERVE_SHARDING = os.environ.get("REPRO_SERVE_SHARDING") == "1"
LB_TILE_Q = int(os.environ.get("REPRO_LB_TILE_Q", "8"))
STORE_BF16 = os.environ.get("REPRO_STORE_BF16") == "1"
# Route the SSM recurrence / attention through fused Pallas kernels:
# probes lower with a shape-compatible bypass (cost_analysis cannot see
# inside a custom call) and the kernel's traffic is added analytically —
# the kernels' raison d'etre is bytes == inputs+outputs, so the analytic
# form is exact by design.
SSM_PALLAS = os.environ.get("REPRO_SSM_PALLAS") == "1"
ATTN_PALLAS = os.environ.get("REPRO_ATTN_PALLAS") == "1"
SEQ_SHARD = os.environ.get("REPRO_SEQ_SHARD") == "1"


def _struct(tree: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree,
        shardings,
    )


def opt_config_for(cfg: ArchConfig) -> OptConfig:
    # Adam state for a 398B model cannot fit a 256-chip v5e pod; use the
    # factored optimizer there (DESIGN.md SS6).
    if cfg.n_params() > 1e11:
        return OptConfig(name="adafactor")
    return OptConfig(name="adamw")


def input_structs(
    cfg: ArchConfig, shape: ShapeConfig, mesh, rules: AxisRules, seq: int
) -> dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    S_in = 1 if shape.kind == "decode" else seq
    specs = batch_specs(cfg, shape, mesh, rules)
    out: dict[str, jax.ShapeDtypeStruct] = {}

    def put(name: str, shp, dtype):
        out[name] = jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(mesh, specs[name])
        )

    if cfg.embed_inputs:
        put("tokens", (B, S_in), jnp.int32)
    else:
        put("frames", (B, S_in, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        put("labels", (B, S_in), jnp.int32)
    if cfg.vision_prefix and shape.kind != "decode":
        put("vision_embeds", (B, min(cfg.vision_prefix, S_in), cfg.d_model),
            jnp.bfloat16)
        put("positions", (B, 3, S_in), jnp.int32)
    return out


def _opt_shardings(opt_shapes: Any, pspecs: Any, mesh) -> Any:
    """Optimizer-state shardings mirroring the param specs (adamw mirrors;
    adafactor vr/vc drop the last / second-to-last param axis)."""
    import jax.tree_util as jtu

    def mirror(sub: Any) -> Any:
        return jax.tree.map(lambda s, p: NamedSharding(mesh, p.spec), sub, pspecs)

    if "mu" in opt_shapes:
        return {"mu": mirror(opt_shapes["mu"]), "nu": mirror(opt_shapes["nu"])}

    pspec_leaves = jax.tree.leaves(pspecs)

    def stat_shard(i: int, st: dict) -> dict:
        spec = pspec_leaves[i].spec
        out = {}
        for k in st:
            if k == "vr":
                out[k] = NamedSharding(mesh, P(*spec[:-1]))
            elif k == "vc":
                out[k] = NamedSharding(mesh, P(*(tuple(spec[:-2]) + tuple(spec[-1:]))))
            else:
                out[k] = NamedSharding(mesh, P(*spec))
        return out

    stats = opt_shapes["stats"]
    flat, tdef = jtu.tree_flatten(
        stats, is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    )
    out = [stat_shard(i, st) for i, st in enumerate(flat)]
    return {"stats": jtu.tree_unflatten(tdef, out)}


def build_lowered(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh,
    rules: AxisRules,
    *,
    seq: int | None = None,
    probe: bool = False,
):
    """Lower the cell's step.  probe=True disables all scans (unrolled
    layers, single-chunk attention/CE/mamba) and remat so HLO costs are
    exact; probe lowerings are for cost analysis only."""
    seq = seq if seq is not None else shape.seq_len
    ssm_impl = "scan"
    if SSM_PALLAS and shape.kind != "decode":
        ssm_impl = "bypass" if probe else "pallas"
    attn_impl = "chunked"
    if ATTN_PALLAS and shape.kind != "decode":
        attn_impl = "bypass" if probe else "pallas"
    model = LM(
        cfg=cfg, mesh=mesh, dp_axes=rules.dp,
        remat=not probe,
        scan_layers=not probe,
        unroll_scans=probe,   # real chunk sizes, while-free HLO for costs
        kv_chunk=4096 if shape.kind == "decode" else 1024,
        mamba_chunk=256,
        ce_chunk=512,
        ssm_impl=ssm_impl,
        attn_impl=attn_impl,
        seq_shard=SEQ_SHARD and shape.kind != "decode",
        **(dict(mamba_scan_dtype=MAMBA_SCAN_DTYPE) if MAMBA_SCAN_DTYPE else {}),
    )
    batch = input_structs(cfg, shape, mesh, rules, seq)
    rng = jax.random.PRNGKey(0)

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        state_shapes = jax.eval_shape(lambda: init_state(model, rng, opt_cfg))
        pspecs = param_shardings(cfg, mesh, rules, state_shapes.params)
        ospecs = _opt_shardings(state_shapes.opt, pspecs, mesh)
        state_structs = type(state_shapes)(
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())),
            params=_struct(state_shapes.params, pspecs),
            opt=_struct(state_shapes.opt, ospecs),
            err=None,
        )
        step = make_train_step(model, opt_cfg)
        return jax.jit(step).lower(state_structs, batch)
    params_shapes = jax.eval_shape(model.init, rng)
    serve = SERVE_SHARDING and shape.kind == "decode"
    pspecs = param_shardings(cfg, mesh, rules, params_shapes, serve=serve)
    if serve:
        # serving checkpoints are bf16 at rest: halves param-read bytes
        params_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape,
                jnp.bfloat16 if (s.dtype == jnp.float32 and len(s.shape) >= 2)
                else s.dtype,
            ),
            params_shapes,
        )
    if shape.kind == "prefill":
        return jax.jit(model.prefill).lower(_struct(params_shapes, pspecs), batch)
    # decode: the KV/SSM cache covers `seq` positions
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, seq)
    )
    cspecs = cache_shardings(cfg, mesh, rules, cache_shapes,
                             batch=shape.global_batch)
    idx = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return jax.jit(model.decode_step).lower(
        _struct(params_shapes, pspecs), _struct(cache_shapes, cspecs),
        batch["tokens"], idx,
    )


def _probe_point(
    cfg: ArchConfig, shape: ShapeConfig, mesh, rules, n_layers: int, seq: int
) -> dict[str, float]:
    cfgm = dataclasses.replace(cfg, n_layers=n_layers)
    lowered = build_lowered(cfgm, shape, mesh, rules, seq=seq, probe=True)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = hlo_analysis.collective_bytes(text, mesh.size)
    n_while = text.count(" while(")
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll.wire_bytes,
        "coll_by_kind": coll.by_kind,
        "while_ops": n_while,
    }


def probe_costs(
    cfg: ArchConfig, shape: ShapeConfig, mesh, rules: AxisRules
) -> dict[str, Any]:
    """Extrapolated per-device HLO costs (see module docstring)."""
    prelude, period, n_repeat = cfg.layout()
    fd = len(prelude)
    plen = len(period)
    S_real = shape.seq_len
    if shape.kind == "train" and S_real <= 4096:
        seqs = [S_real]
    else:
        seqs = [2048, 4096]
    pts: dict[tuple[int, int], dict[str, float]] = {}
    for m in (1, 2):
        for s in seqs:
            pts[(m, s)] = _probe_point(cfg, shape, mesh, rules, fd + m * plen, s)

    def extrapolate(metric: str) -> float:
        if len(seqs) == 1:
            s = seqs[0]
            d = pts[(2, s)][metric] - pts[(1, s)][metric]
            base = pts[(1, s)][metric] - d
            return base + n_repeat * d
        s1, s2 = seqs
        d1 = pts[(2, s1)][metric] - pts[(1, s1)][metric]
        d2 = pts[(2, s2)][metric] - pts[(1, s2)][metric]
        b1 = pts[(1, s1)][metric] - d1
        b2 = pts[(1, s2)][metric] - d2
        if shape.kind == "decode":
            # per-period cost is affine in cache length
            slope = (d2 - d1) / (s2 - s1)
            dS = d1 + slope * (S_real - s1)
            bslope = (b2 - b1) / (s2 - s1)
            bS = b1 + bslope * (S_real - s1)
        else:
            # per-period cost = a*S + q*S^2 ; base is linear in S
            q = (d2 / s2 - d1 / s1) / (s2 - s1)
            a = d1 / s1 - q * s1
            dS = a * S_real + q * S_real * S_real
            bS = b2 * (S_real / s2)
        return max(bS + n_repeat * dS, 0.0)

    out = {
        "flops": extrapolate("flops"),
        "bytes": extrapolate("bytes"),
        "coll": extrapolate("coll"),
        "probe_points": {f"{m}x{s}": pts[(m, s)] for (m, s) in pts},
    }
    if shape.kind == "train":
        for k in ("flops", "bytes", "coll"):
            out[k] *= REMAT_FACTOR
        out["remat_factor"] = REMAT_FACTOR
    if SSM_PALLAS and shape.kind != "decode":
        # analytic traffic of the fused selective-scan kernel (per device):
        # inputs delta,u (B,S,C_loc) + Bm,Cm (B,S,N) + output y (B,S,C_loc)
        n_mamba = sum(
            1 for i in range(cfg.n_layers) if cfg.layer_spec(i).mixer == "mamba"
        )
        if n_mamba:
            mesh_model = mesh.shape.get("model", 1)
            dp = 1
            for a in rules.dp:
                dp *= mesh.shape.get(a, 1)
            B_loc = max(shape.global_batch // dp, 1)
            C_loc = cfg.d_inner_ // mesh_model
            N = cfg.ssm_state
            k_bytes = B_loc * S_real * (3 * C_loc + 2 * N) * 4.0
            k_flops = B_loc * S_real * C_loc * N * 8.0
            mult = (2.0 + 1.0) if shape.kind == "train" else 1.0  # fwd+rec+bwd
            out["bytes"] += n_mamba * k_bytes * mult
            out["flops"] += n_mamba * k_flops * mult
            out["ssm_pallas_added"] = {
                "layers": n_mamba, "bytes_per_layer": k_bytes,
                "flops_per_layer": k_flops,
            }
    if ATTN_PALLAS and shape.kind != "decode":
        # analytic traffic of the fused flash-attention kernel: q/k/v reads
        # + out write (bf16), flops = 2 matmuls over the (masked) scores
        n_attn = sum(
            1 for i in range(cfg.n_layers) if cfg.layer_spec(i).mixer == "attn"
        )
        if n_attn:
            mesh_model = mesh.shape.get("model", 1)
            dp = 1
            for a in rules.dp:
                dp *= mesh.shape.get(a, 1)
            B_loc = max(shape.global_batch // dp, 1)
            hq = cfg.n_heads
            hq_loc = hq // mesh_model if hq % mesh_model == 0 else hq
            hkv_loc = (
                cfg.n_kv_heads // mesh_model
                if cfg.n_kv_heads % mesh_model == 0
                else cfg.n_kv_heads
            )
            D = cfg.head_dim
            a_bytes = B_loc * S_real * D * 2.0 * (2 * hq_loc + 2 * hkv_loc)
            # causal wedge halves the score work; sliding window caps it
            pairs = 0.0
            for i in range(cfg.n_layers):
                sp = cfg.layer_spec(i)
                if sp.mixer != "attn":
                    continue
                if sp.window:
                    pairs += min(S_real * sp.window, S_real * S_real / 2)
                elif cfg.causal:
                    pairs += S_real * S_real / 2
                else:
                    pairs += S_real * S_real
            a_flops = 4.0 * B_loc * hq_loc * D * pairs
            mult = 4.0 if shape.kind == "train" else 1.0   # fwd+rec+bwd(2x)
            out["bytes"] += n_attn * a_bytes * mult
            out["flops"] += a_flops * mult
            out["attn_pallas_added"] = {
                "layers": n_attn, "bytes_per_layer": a_bytes,
                "flops_total": a_flops,
            }
    return out


def model_flops_for(cfg: ArchConfig, shape: ShapeConfig) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch     # one token per sequence


def ideal_bytes_for(cfg: ArchConfig, shape: ShapeConfig, n_dev: int) -> float:
    """Per-device mandatory-HBM-traffic floor (speed-of-light memory)."""
    n = cfg.n_params()
    if shape.kind == "train":
        # optimizer floor: fp32 params r+w, adam m/v r+w (adafactor ~r+w p)
        mult = 12.0 if cfg.n_params() > 1e11 else 24.0
        return mult * n / n_dev
    if shape.kind == "prefill":
        return 4.0 * n / n_dev     # fp32 params read once (floor)
    # decode: params read (all experts touched when B*k >= E) + cache read
    B, S = shape.global_batch, shape.seq_len
    if cfg.n_experts and B * cfg.top_k < cfg.n_experts:
        n_read = cfg.n_active_params()
    else:
        n_read = n
    cache_b = 0.0
    for i in range(cfg.n_layers):
        if cfg.layer_spec(i).mixer == "attn":
            cache_b += 2.0 * B * S * cfg.n_kv_heads * cfg.head_dim * 2
        else:
            cache_b += B * cfg.d_inner_ * cfg.ssm_state * 4.0
    param_bytes = 2.0 if SERVE_SHARDING else 4.0   # bf16 serving weights
    return (param_bytes * n_read + cache_b) / n_dev


def run_cell(
    arch_name: str, shape_name: str, mesh_kind: str, out_dir: str,
    *, do_probe: bool = True,
) -> dict[str, Any]:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    skip = shape_applicability(cfg, shape)
    result: dict[str, Any] = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
    }
    if skip:
        result["status"] = "skip"
        result["reason"] = skip
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = AxisRules.for_mesh(mesh)

    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, rules)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()

    result.update(
        status="ok",
        n_devices=mesh.size,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            k: getattr(mem, k)
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
    )

    if do_probe and mesh_kind == "single":   # roofline table is single-pod
        pc = probe_costs(cfg, shape, mesh, rules)
        coll = hlo_analysis.CollectiveStats(wire_bytes=pc["coll"])
        rf = hlo_analysis.roofline(
            {"flops": pc["flops"], "bytes accessed": pc["bytes"]},
            coll,
            model_flops=model_flops_for(cfg, shape),
            n_devices=mesh.size,
            ideal_bytes_per_device=ideal_bytes_for(cfg, shape, mesh.size),
        )
        result["roofline"] = rf
        result["probe"] = pc["probe_points"]

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{mesh_kind}__{arch_name}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def run_paper_cell(mesh_kind: str, out_dir: str) -> dict[str, Any]:
    """Dry-run the paper's own workload: distributed LB_ENHANCED NN-DTW."""
    from repro.configs.paper_dtw import PAPER_SEARCH
    from repro.search.cascade import CascadeConfig
    from repro.search.distributed import make_distributed_search
    from repro.search.engine import EngineConfig

    pc = PAPER_SEARCH
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = AxisRules.for_mesh(mesh)
    cfg = EngineConfig(
        cascade=CascadeConfig(
            w=pc.w, v=pc.v, candidate_chunk=pc.candidate_chunk,
            use_pallas=False,
        ),
        verify_chunk=pc.verify_chunk,
        k=pc.k,
    )
    step = make_distributed_search(mesh, cfg, data_axes=rules.dp,
                                   query_axis="model")
    N, L, Q = pc.n_store, pc.length, pc.n_queries
    dp = rules.dp
    sh = lambda spec: NamedSharding(mesh, spec)
    args = (
        jax.ShapeDtypeStruct((N, L), jnp.float32, sharding=sh(P(dp, None))),
        jax.ShapeDtypeStruct((N,), jnp.int32, sharding=sh(P(dp))),
        jax.ShapeDtypeStruct((N, L), jnp.float32, sharding=sh(P(dp, None))),
        jax.ShapeDtypeStruct((N, L), jnp.float32, sharding=sh(P(dp, None))),
        jax.ShapeDtypeStruct((N, 4), jnp.float32, sharding=sh(P(dp, None))),
        jax.ShapeDtypeStruct((N, 2), jnp.bool_, sharding=sh(P(dp, None))),
        jax.ShapeDtypeStruct((Q, L), jnp.float32, sharding=sh(P("model", None))),
    )
    t0 = time.time()
    lowered = jax.jit(step).lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    coll = hlo_analysis.collective_bytes(compiled.as_text(), mesh.size)

    # Analytic per-device costs (the verification while-loop's trip count is
    # data-dependent; we charge the expected number of verify rounds):
    n_dev = mesh.size
    q_shards = mesh.shape["model"]
    dp_size = n_dev // q_shards
    N_loc, Q_loc = N // dp_size, Q // q_shards
    nb = min(pc.v, pc.w, L // 2)
    store_bytes = 2 if STORE_BF16 else 4   # series+envelope element size
    lb_flops = Q_loc * N_loc * (4.0 * L + 4.0 * nb * nb)        # bridge + bands
    dtw_flops = Q_loc * pc.expected_verify * 10.0 * L * L       # wavefront DP
    sort_flops = Q_loc * N_loc * 30.0                           # argsort log N
    flops = lb_flops + dtw_flops + sort_flops
    bytes_ = (
        N_loc * L * store_bytes * 3       # series + envelopes read per tile
        * max(Q_loc // LB_TILE_Q, 1)      # re-read per query kernel tile
        + Q_loc * N_loc * 4 * 4           # lb matrix + argsort traffic
    )
    useful = Q * (N * 4.0 * L + pc.expected_verify * 2.0 * L * (2 * pc.w + 1))
    rf = hlo_analysis.roofline(
        {"flops": flops, "bytes accessed": float(bytes_)}, coll,
        model_flops=useful, n_devices=n_dev,
    )
    result = {
        "arch": "paper-dtw-search", "shape": pc.name, "mesh": mesh_kind,
        "status": "ok", "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            k: getattr(mem, k)
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "roofline": rf,
        "note": "flops/bytes analytic (data-dependent verify loop); "
                "collectives parsed from compiled HLO",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"{mesh_kind}__paper-dtw-search__{pc.name}.json"),
              "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    elif args.arch:
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(args.arch, s) for s in shapes]

    failures = 0
    for mk in meshes:
        if args.paper:
            r = run_paper_cell(mk, args.out)
            print(f"[{mk}] paper-dtw-search: {r['status']} "
                  f"compile={r.get('compile_s')}s "
                  f"dominant={r['roofline']['dominant']}")
        for a, s in cells:
            path = os.path.join(args.out, f"{mk}__{a}__{s}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[{mk}] {a} x {s}: cached", flush=True)
                continue
            try:
                t0 = time.time()
                r = run_cell(a, s, mk, args.out, do_probe=not args.no_probe)
                if r["status"] == "skip":
                    print(f"[{mk}] {a} x {s}: SKIP ({r['reason']})", flush=True)
                    with open(path, "w") as f:
                        json.dump(r, f, indent=1)
                else:
                    rf = r.get("roofline")
                    extra = (
                        f"dominant={rf['dominant']} "
                        f"frac={rf['roofline_fraction']:.3f}"
                        if rf else ""
                    )
                    print(
                        f"[{mk}] {a} x {s}: ok wall={time.time()-t0:.0f}s "
                        f"compile={r['compile_s']}s temp_gb="
                        f"{r['memory'].get('temp_size_in_bytes', 0)/2**30:.2f} "
                        + extra,
                        flush=True,
                    )
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"[{mk}] {a} x {s}: FAIL {type(e).__name__}: {e}",
                      flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
