"""Production training driver.

Single-host usage (reduced preset runs on this CPU container):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      --preset reduced --steps 50 --ckpt-dir /tmp/ckpt

On a pod the same driver runs under the production mesh: the mesh is
re-planned from the live device count (elastic), the latest checkpoint is
restored with resharding, the data pipeline resumes from its cursor, and a
heartbeat file is refreshed every step for the straggler monitor.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, get_arch, reduced
from repro.data.tokens import TokenPipeline
from repro.distributed.elastic import Heartbeat, plan_mesh
from repro.distributed.sharding import AxisRules, param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "reduced":
        cfg = reduced(cfg)

    n_dev = len(jax.devices())
    mesh = None
    rules = AxisRules()
    if n_dev > 1:
        plan = plan_mesh(n_dev)
        mesh = make_host_mesh(plan.shape, plan.axes)
        rules = AxisRules.for_mesh(mesh)
        print(f"mesh: {plan.shape} {plan.axes}")

    model = LM(cfg=cfg, mesh=mesh, dp_axes=rules.dp)
    opt_cfg = OptConfig(lr=args.lr, warmup=10)
    state = init_state(model, jax.random.PRNGKey(0), opt_cfg)
    if mesh is not None:
        pspecs = param_shardings(cfg, mesh, rules, state.params)
        state = dataclasses.replace(
            state,
            params=jax.device_put(state.params, pspecs),
            opt=jax.tree.map(
                lambda x: x, state.opt
            ),
        )

    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq, seed=0)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        like = jax.tree.map(jnp.zeros_like, state)
        state, extra = restore_checkpoint(args.ckpt_dir, like)
        pipe.restore(extra["pipeline"])
        start = int(state.step)
        print(f"restored step {start} from {args.ckpt_dir}")

    hb = Heartbeat(args.heartbeat) if args.heartbeat else None
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, grad_accum=args.grad_accum)
    )

    t0 = time.time()
    for i in range(start, args.steps):
        batch_np = pipe.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if mesh is not None:
            bs = NamedSharding(mesh, P(rules.dp, None))
            batch = {k: jax.device_put(v, bs) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if hb:
            hb.beat(i)
        if (i + 1) % args.log_every == 0 or i == start:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {i + 1:5d}  loss {loss:.4f}  "
                  f"({dt / max(i + 1 - start, 1):.2f}s/step)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(
                args.ckpt_dir, i + 1, state, extra={"pipeline": pipe.state()}
            )
    if args.ckpt_dir:
        save_checkpoint(
            args.ckpt_dir, args.steps, state, extra={"pipeline": pipe.state()}
        )
    print("done.")


if __name__ == "__main__":
    main()
