"""Production mesh construction.

Pure functions only — importing this module must never touch jax device
state (the dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; smoke tests see the default single device).
"""

from __future__ import annotations

import math

import jax


def _axis_types_kw(n: int) -> dict:
    """``axis_types=Auto`` where the installed jax knows it (>= 0.5),
    nothing on older versions (Auto is their only behaviour anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return {} if axis_type is None else {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The target deployment mesh: one v5e-class 16x16 pod (256 chips), or
    two pods (512 chips) with a leading pure-DP ``pod`` axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax for the dry-run)"
        )
    return jax.make_mesh(
        shape,
        axes,
        devices=devices,
        **_axis_types_kw(len(axes)),
    )


def make_host_mesh(
    shape: tuple[int, ...], axes: tuple[str, ...]
) -> jax.sharding.Mesh:
    """Small helper mesh for tests (uses however many devices exist)."""
    n = math.prod(shape)
    return jax.make_mesh(
        shape,
        axes,
        devices=jax.devices()[:n],
        **_axis_types_kw(len(axes)),
    )
