"""Plain-Python/numpy oracles: direct, loop-based transcriptions of the
paper's equations.  Slow and unvectorised on purpose — these are the ground
truth the JAX implementations and Pallas kernels are tested against.
"""

from __future__ import annotations

import numpy as np


def delta(a: float, b: float) -> float:
    return float((a - b) ** 2)


def dtw(a, b, w=None):
    """Eq. 1-2 with the Sakoe-Chiba window; returns D(L, L) (squared cost)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    L = len(a)
    if w is None or w >= L:
        w = L
    D = np.full((L, L), np.inf)
    for i in range(L):
        for j in range(max(0, i - w), min(L, i + w + 1)):
            c = delta(a[i], b[j])
            if i == 0 and j == 0:
                D[i, j] = c
            else:
                best = np.inf
                if i > 0:
                    best = min(best, D[i - 1, j])
                if j > 0:
                    best = min(best, D[i, j - 1])
                if i > 0 and j > 0:
                    best = min(best, D[i - 1, j - 1])
                D[i, j] = c + best
    return D[L - 1, L - 1]


def envelope(b, w):
    """Eqs. 5-6."""
    b = np.asarray(b, dtype=np.float64)
    L = len(b)
    u = np.empty(L)
    lo = np.empty(L)
    for i in range(L):
        s, e = max(0, i - w), min(L, i + w + 1)
        u[i] = b[s:e].max()
        lo[i] = b[s:e].min()
    return u, lo


def lb_keogh(a, b, w):
    """Eq. 7."""
    a = np.asarray(a, dtype=np.float64)
    u, lo = envelope(b, w)
    res = 0.0
    for i in range(len(a)):
        if a[i] > u[i]:
            res += delta(a[i], u[i])
        elif a[i] < lo[i]:
            res += delta(a[i], lo[i])
    return res


def lb_improved(a, b, w):
    """Eqs. 8-9."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    u, lo = envelope(b, w)
    a_proj = np.clip(a, lo, u)
    return lb_keogh(a, b, w) + lb_keogh(b, a_proj, w)


def lb_new(a, b, w):
    """Eq. 10."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    L = len(a)
    w = min(w, L)
    res = delta(a[0], b[0]) + delta(a[-1], b[-1])
    for i in range(1, L - 1):
        s, e = max(0, i - w), min(L, i + w + 1)
        res += min(delta(a[i], b[j]) for j in range(s, e))
    return res


def lb_yi(a, b):
    """Eq. 4."""
    a = np.asarray(a, dtype=np.float64)
    bmax, bmin = float(np.max(b)), float(np.min(b))
    res = 0.0
    for x in a:
        if x > bmax:
            res += delta(x, bmax)
        elif x < bmin:
            res += delta(x, bmin)
    return res


def lb_enhanced(a, b, w, v):
    """Algorithm 1 (without the early-abandon cutoff): left/right elastic
    bands for the ``n_bands`` outermost positions + Keogh bridge."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    L = len(a)
    nb = max(0, min(L // 2, w, v))
    res = 0.0
    # left bands i = 0 .. nb-1  (1-indexed 1..n_bands in the paper)
    for i in range(nb):
        cells = [delta(a[j], b[i]) for j in range(max(0, i - w), i + 1)]
        cells += [delta(a[i], b[k]) for k in range(max(0, i - w), i + 1)]
        res += min(cells)
    # right bands
    for i in range(L - nb, L):
        cells = [delta(a[j], b[i]) for j in range(i, min(L, i + w + 1))]
        cells += [delta(a[i], b[k]) for k in range(i, min(L, i + w + 1))]
        res += min(cells)
    # Keogh bridge
    u, lo = envelope(b, w)
    for i in range(nb, L - nb):
        if a[i] > u[i]:
            res += delta(a[i], u[i])
        elif a[i] < lo[i]:
            res += delta(a[i], lo[i])
    return res


def lb_enhanced_bands(a, b, w, v):
    """Algorithm 1 lines 1-11 (band sum only)."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    L = len(a)
    nb = max(0, min(L // 2, w, v))
    res = 0.0
    for i in range(nb):
        cells = [delta(a[j], b[i]) for j in range(max(0, i - w), i + 1)]
        cells += [delta(a[i], b[k]) for k in range(max(0, i - w), i + 1)]
        res += min(cells)
    for i in range(L - nb, L):
        cells = [delta(a[j], b[i]) for j in range(i, min(L, i + w + 1))]
        cells += [delta(a[i], b[k]) for k in range(i, min(L, i + w + 1))]
        res += min(cells)
    return res
