"""Core paper math: DTW, envelopes, and the LB_ENHANCED lower-bound family."""

from repro.core.distances import (
    delta,
    squared_euclidean,
    squared_euclidean_matrix,
    znorm,
)
from repro.core.dtw import (
    cost_matrix,
    dtw,
    dtw_band_blocked,
    dtw_batch,
    dtw_pairs,
    row_block_policy,
)
from repro.core.envelopes import envelope, envelope_naive, sliding_reduce
from repro.core.lower_bounds import (
    BOUND_NAMES,
    get_bound,
    lb_enhanced,
    lb_enhanced_bands,
    lb_enhanced_env,
    lb_enhanced_matrix,
    lb_improved,
    lb_keogh,
    lb_keogh_env,
    lb_keogh_matrix,
    lb_kim,
    lb_kim_paper,
    lb_new,
    lb_yi,
)

__all__ = [
    "BOUND_NAMES",
    "cost_matrix",
    "delta",
    "dtw",
    "dtw_band_blocked",
    "dtw_batch",
    "dtw_pairs",
    "row_block_policy",
    "envelope",
    "envelope_naive",
    "get_bound",
    "lb_enhanced",
    "lb_enhanced_bands",
    "lb_enhanced_env",
    "lb_enhanced_matrix",
    "lb_improved",
    "lb_keogh",
    "lb_keogh_env",
    "lb_keogh_matrix",
    "lb_kim",
    "lb_kim_paper",
    "lb_new",
    "lb_yi",
    "sliding_reduce",
    "squared_euclidean",
    "squared_euclidean_matrix",
    "znorm",
]
