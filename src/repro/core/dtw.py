"""Banded (Sakoe-Chiba) Dynamic Time Warping in pure JAX.

Paper Eqs. 1-2 with the warping-window constraint ``|i - j| <= w``
(SS II-A).  We minimise ``D(L, L)`` directly — squared-cost, no sqrt.

TPU adaptation (DESIGN.md SS3): the DP recurrence has an intra-row sequential
dependency (``D(i, j)`` needs ``D(i, j-1)``), so rows cannot be vectorised.
Cells on one *anti-diagonal* ``d = i + j`` depend only on diagonals ``d-1``
and ``d-2``, so we scan over the ``2L - 1`` anti-diagonals and vectorise each
diagonal across the VPU.  Work is O(L^2) elementwise ops (band-masked), state
is O(L).  The Pallas kernel (kernels/dtw_band.py) additionally packs a batch
of (query, candidate) pairs across vector lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INF = jnp.inf


@functools.partial(jax.jit, static_argnames=("w",))
def dtw(a: Array, b: Array, w: int | None = None) -> Array:
    """``DTW_w(a, b)`` for two equal-length 1-D series (squared cost).

    Args:
      a, b: ``(L,)`` series.
      w: Sakoe-Chiba half-width; ``None`` or ``>= L`` means unconstrained.
         ``w == 0`` is the squared Euclidean distance.

    Returns:
      Scalar ``D(L, L)``.
    """
    L = a.shape[-1]
    if w is None or w >= L:
        w = L
    ii = jnp.arange(L)

    def step(carry, d):
        d1, d2 = carry  # diagonals d-1, d-2; index i holds D(i, d-i)
        jj = d - ii
        bj = b[jnp.clip(jj, 0, L - 1)]
        cost = (a - bj) ** 2
        up = d1                                        # D(i, j-1)
        left = jnp.concatenate([jnp.full((1,), _INF, d1.dtype), d1[:-1]])   # D(i-1, j)
        diag = jnp.concatenate([jnp.full((1,), _INF, d2.dtype), d2[:-1]])   # D(i-1, j-1)
        best = jnp.minimum(jnp.minimum(up, left), diag)
        best = jnp.where((ii == 0) & (jj == 0), 0.0, best)
        nd = cost + best
        valid = (jj >= 0) & (jj < L) & (jnp.abs(ii - jj) <= w)
        nd = jnp.where(valid, nd, _INF)
        return (nd, d1), None

    init = (jnp.full((L,), _INF, a.dtype), jnp.full((L,), _INF, a.dtype))
    (dlast, _), _ = lax.scan(step, init, jnp.arange(2 * L - 1))
    return dlast[L - 1]


@functools.partial(jax.jit, static_argnames=("w",))
def dtw_batch(a: Array, b: Array, w: int | None = None) -> Array:
    """Batched ``DTW_w`` over leading axes: ``(..., L) x (..., L) -> (...)``."""
    fn = dtw
    for _ in range(max(a.ndim, b.ndim) - 1):
        fn = jax.vmap(fn, in_axes=(0, 0, None))
    return fn(a, b, w)


def dtw_pairs(q: Array, c: Array, w: int | None = None) -> Array:
    """All-pairs ``DTW_w``: ``(Q, L) x (C, L) -> (Q, C)``.

    This is the expensive verification step the lower-bound cascade exists to
    avoid; the engine only calls it on cascade survivors.
    """
    per_q = jax.vmap(dtw, in_axes=(None, 0, None))     # (C,)
    return jax.vmap(per_q, in_axes=(0, None, None))(q, c, w)


def cost_matrix(a: Array, b: Array, w: int | None = None) -> Array:
    """Full DP matrix ``D`` (O(L^2) memory) — debugging / figures only."""
    L = a.shape[-1]
    if w is None or w >= L:
        w = L
    delta = (a[:, None] - b[None, :]) ** 2
    band = jnp.abs(jnp.arange(L)[:, None] - jnp.arange(L)[None, :]) <= w
    delta = jnp.where(band, delta, _INF)

    def row_step(prev_row, xs):
        drow, i = xs

        def col_step(left_val, xs2):
            dij, up, diag_ = xs2
            best = jnp.minimum(jnp.minimum(left_val, up), diag_)
            val = dij + best
            return val, val

        diag_prev = jnp.concatenate(
            [jnp.where(i == 0, 0.0, _INF)[None], prev_row[:-1]]
        )
        _, row = lax.scan(col_step, _INF, (drow, prev_row, diag_prev))
        return row, row

    init = jnp.full((L,), _INF)
    _, rows = lax.scan(row_step, init, (delta, jnp.arange(L)))
    return rows


def dtw_envelope_bound_gap(a: Array, b: Array, lb: Array, w: int | None = None) -> Array:
    """Tightness ``lb / DTW_w(a, b)`` (paper Eq. 15) for diagnostics."""
    d = dtw(a, b, w)
    return jnp.where(d > 0, lb / d, 1.0)
