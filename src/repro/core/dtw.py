"""Banded (Sakoe-Chiba) Dynamic Time Warping in pure JAX.

Paper Eqs. 1-2 with the warping-window constraint ``|i - j| <= w``
(SS II-A).  We minimise ``D(L, L)`` directly — squared-cost, no sqrt.

TPU adaptation (DESIGN.md SS3): the DP recurrence has an intra-row sequential
dependency (``D(i, j)`` needs ``D(i, j-1)``), so rows cannot be vectorised.
Cells on one *anti-diagonal* ``d = i + j`` depend only on diagonals ``d-1``
and ``d-2``, so we scan over the ``2L - 1`` anti-diagonals.

Band-packed layout (this is what makes work O(L*W), not O(L^2)): a cell is
addressed by its anti-diagonal ``d`` and its *diagonal offset*
``k = i - j + w`` in ``[0, 2w]``.  The state per diagonal is a dense
``Wb = 2w + 1`` vector instead of a length-``L`` one, and the recurrence is
pure shifts in ``k``:

    S_d[k] = cost(i, j) + min(S_{d-1}[k-1], S_{d-1}[k+1], S_{d-2}[k])

with ``i = (d + k - w) / 2`` (cells exist only when ``d + k - w`` is even —
half the lanes idle, which still wins for ``w << L``).  The cost gathers
``a[(d+k-w)//2]`` / ``b[(d-k+w)//2]`` — contiguous slices of the
*2x-duplicated* series ``A2[t] = a[t // 2]`` (and the flipped duplicate of
``b``), so every step is two ``dynamic_slice`` calls, no gathers.

Early abandon (PrunedDTW-style, arXiv:2102.05221): every warping path
crosses anti-diagonals ``d`` or ``d-1``, and path prefixes only grow, so
``min(S_d, S_{d-1})`` lower-bounds the final DTW.  When a ``cutoff`` is
given and that frontier minimum exceeds it, the state is poisoned to +inf
and the call returns +inf — the caller learns "distance > cutoff" without
paying for the rest of the matrix.

Row-block layout (``dtw_band_blocked``): the Pallas kernel's early-exit
grid (kernels/dtw_band.py) groups the ``2L - 1`` anti-diagonals into
``row_block_policy(L)``-sized blocks and makes abandon decisions only at
block boundaries.  Because the frontier minimum is *monotone
non-decreasing* in ``d`` (each new cell is ``cost + min`` of frontier
entries), checking at block boundaries abandons exactly the same lanes as
checking every step — the coarser granularity trades a later poison for a
much cheaper inner loop and real block skipping.  ``dtw_band_blocked`` is
the batched jnp mirror of that layout: same block boundaries, same
frontier test, so kernel and reference stay bit-comparable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INF = jnp.inf


@functools.partial(jax.jit, static_argnames=("w",))
def dtw(a: Array, b: Array, w: int | None = None, cutoff=None) -> Array:
    """``DTW_w(a, b)`` for two equal-length 1-D series (squared cost).

    Args:
      a, b: ``(L,)`` series.
      w: Sakoe-Chiba half-width; ``None`` or ``>= L`` means unconstrained.
         ``w == 0`` is the squared Euclidean distance.
      cutoff: optional scalar early-abandon threshold.  Whenever the true
        distance is strictly below ``cutoff`` the result is exact; otherwise
        the result is ``>= cutoff`` (usually +inf — the lane abandons as
        soon as the frontier minimum proves the cutoff unreachable).

    Returns:
      Scalar ``D(L, L)`` (or +inf on abandon).
    """
    L = a.shape[-1]
    if w is None or w >= L:
        w = L
    wb = min(w, L - 1)                 # |i - j| <= L - 1 always holds
    Wb = 2 * wb + 1
    dt = a.dtype
    if cutoff is None:
        cutoff = jnp.asarray(_INF, dt)
    # 2x-duplicated series, shifted so slice starts stay non-negative:
    #   a2p[wb + t] = a[t // 2]     b2p[wb + t] = b[(2L - 1 - t) // 2]
    pad_len = 2 * L + Wb + wb
    a2 = jnp.repeat(a, 2, axis=-1)
    b2f = jnp.flip(jnp.repeat(b, 2, axis=-1), axis=-1)
    a2p = jnp.zeros((pad_len,), dt).at[wb:wb + 2 * L].set(a2)
    b2p = jnp.zeros((pad_len,), dt).at[wb:wb + 2 * L].set(b2f)
    kk = jnp.arange(Wb)

    def step(carry, d):
        d1, d2 = carry                                   # S_{d-1}, S_{d-2}
        a_at = lax.dynamic_slice(a2p, (d,), (Wb,))       # a[(d + k - wb)//2]
        b_at = lax.dynamic_slice(b2p, (2 * L - 1 - d,), (Wb,))
        cost = (a_at - b_at) ** 2
        inf1 = jnp.full((1,), _INF, dt)
        dep_l = jnp.concatenate([inf1, d1[:-1]])         # S_{d-1}[k-1]
        dep_r = jnp.concatenate([d1[1:], inf1])          # S_{d-1}[k+1]
        best = jnp.minimum(jnp.minimum(dep_l, dep_r), d2)
        origin = (d == 0) & (kk == wb)
        nd = cost + jnp.where(origin, 0.0, best)
        t = d + kk - wb                                  # 2i
        s = d - kk + wb                                  # 2j
        valid = ((t & 1) == 0) & (t >= 0) & (t <= 2 * L - 2) \
            & (s >= 0) & (s <= 2 * L - 2)
        nd = jnp.where(valid, nd, _INF)
        # every path crosses diagonal d or d-1 -> frontier min is a LB
        dead = jnp.min(jnp.minimum(nd, d1)) > cutoff
        nd = jnp.where(dead, _INF, nd)
        d1 = jnp.where(dead, _INF, d1)
        return (nd, d1), None

    init = (jnp.full((Wb,), _INF, dt), jnp.full((Wb,), _INF, dt))
    (dlast, _), _ = lax.scan(step, init, jnp.arange(2 * L - 1))
    return dlast[wb]


def row_block_policy(L: int) -> int:
    """Anti-diagonals per row block for the early-exit banded sweep.

    Shared by the Pallas kernel (kernels/dtw_band.py) and the jnp reference
    (``dtw_band_blocked``) so abandon decisions land on identical block
    boundaries.  ~8 blocks per sweep, 64-step multiples: coarse enough that
    the per-block frontier reduction is amortised, fine enough that a
    poisoned tile skips most of its remaining anti-diagonals.
    """
    D = 2 * L - 1
    return min(D, max(64, -(-(D // 8) // 64) * 64))


def band_step(d, carry, a2p, b2p, kk, *, L: int, w: int,
              a_off=0, b_off=0):
    """One anti-diagonal of the band-packed recurrence (no abandon test).

    ``carry = (S_{d-1}, S_{d-2})`` as ``(P, Wb)`` blocks; returns
    ``(S_d, S_{d-1})``.  Shared verbatim by the Pallas kernel bodies
    (kernels/dtw_band.py) and the jnp reference below — one definition is
    what keeps kernel and oracle bit-comparable by construction.  ``kk`` is
    the per-lane diagonal-offset iota; lanes beyond ``2w`` (the kernel's
    128-multiple padding) are masked invalid.

    ``a_off``/``b_off`` declare that ``a2p``/``b2p`` are *windows* of the
    packed operands starting at those global columns (the streaming
    kernel's double-buffered per-row-block windows); the resident callers
    pass whole operands and leave the defaults at 0.  The arithmetic on
    the window is identical — only the slice origin moves — so windowed
    and resident sweeps stay bit-comparable by construction too.
    """
    d1, d2 = carry
    tp, Wb = d1.shape
    dt = d1.dtype
    a_at = lax.dynamic_slice(a2p, (0, d - a_off), (tp, Wb))  # a[(d+k-w)//2]
    b_at = lax.dynamic_slice(b2p, (0, 2 * L - 1 - d - b_off), (tp, Wb))
    diff = a_at - b_at
    cost = diff * diff
    inf_col = jnp.full((tp, 1), _INF, dt)
    dep_l = jnp.concatenate([inf_col, d1[:, :-1]], axis=-1)  # S_{d-1}[k-1]
    dep_r = jnp.concatenate([d1[:, 1:], inf_col], axis=-1)   # S_{d-1}[k+1]
    best = jnp.minimum(jnp.minimum(dep_l, dep_r), d2)
    origin = (d == 0) & (kk == w)
    nd = cost + jnp.where(origin, 0.0, best)
    t = d + kk - w                                       # 2i
    s = d - kk + w                                       # 2j
    valid = ((t & 1) == 0) & (t >= 0) & (t <= 2 * L - 2) \
        & (s >= 0) & (s <= 2 * L - 2) & (kk <= 2 * w)
    nd = jnp.where(valid, nd, _INF)
    return nd, d1


def _band_blocked_scan(
    a: Array,
    b: Array,
    w: int | None,
    cutoff: Array | None,
    row_block: int | None,
) -> tuple[Array, Array]:
    """Shared row-block-checked band sweep: ``((P,) values, (P,) death)``.

    The single definition of the blocked abandon schedule — the same block
    boundaries, frontier test, and poisoning the Pallas kernel's early-exit
    grid uses — consumed by both ``dtw_band_blocked`` (values) and
    ``dtw_band_death_blocks`` (liveness mirror), so the two cannot drift.
    ``death[p]`` is the index of the first row block whose boundary check
    abandoned lane ``p`` (``n_blocks - 1`` for survivors).
    """
    P, L = a.shape
    if w is None or w >= L:
        w = L
    wb = min(w, L - 1)
    Wb = 2 * wb + 1
    dt = a.dtype
    if cutoff is None:
        cutoff = jnp.full((P,), _INF, dt)
    else:
        cutoff = jnp.broadcast_to(jnp.asarray(cutoff, dt), (P,))
    cut = cutoff[:, None]
    R = row_block if row_block is not None else row_block_policy(L)
    D = 2 * L - 1
    n_blocks = -(-D // R)
    pad_len = 2 * L + Wb + wb
    a2 = jnp.repeat(a, 2, axis=-1)
    b2f = jnp.flip(jnp.repeat(b, 2, axis=-1), axis=-1)
    a2p = jnp.zeros((P, pad_len), dt).at[:, wb:wb + 2 * L].set(a2)
    b2p = jnp.zeros((P, pad_len), dt).at[:, wb:wb + 2 * L].set(b2f)
    kk = lax.broadcasted_iota(jnp.int32, (P, Wb), 1)

    def step(carry, d):
        (d1, d2), death, found = carry
        nd, d1 = band_step(d, (d1, d2), a2p, b2p, kk, L=L, w=wb)
        # abandon only at row-block boundaries (the kernel's grid layout)
        check = ((d + 1) % R == 0) | (d == D - 1)
        fmin = jnp.min(jnp.minimum(nd, d1), axis=-1, keepdims=True)
        dead = check & (fmin > cut)
        newly = dead[:, 0] & jnp.logical_not(found)
        death = jnp.where(newly, d // R, death)
        found = found | dead[:, 0]
        nd = jnp.where(dead, _INF, nd)
        d1 = jnp.where(dead, _INF, d1)
        return ((nd, d1), death, found), None

    init = (
        (jnp.full((P, Wb), _INF, dt), jnp.full((P, Wb), _INF, dt)),
        jnp.full((P,), n_blocks - 1, jnp.int32),
        jnp.zeros((P,), bool),
    )
    ((dlast, _), death, _), _ = lax.scan(step, init, jnp.arange(D))
    return dlast[:, wb], death


@functools.partial(jax.jit, static_argnames=("w", "row_block"))
def dtw_band_blocked(
    a: Array,
    b: Array,
    w: int | None = None,
    cutoff: Array | None = None,
    *,
    row_block: int | None = None,
) -> Array:
    """Batched band-packed DTW with row-block abandon checks.

    ``(P, L) x (P, L) -> (P,)`` — the pure-jnp mirror of the Pallas
    kernel's ``(pair_tile, row_block)`` early-exit grid: the frontier test
    runs only at row-block boundaries (every ``row_block`` anti-diagonals
    and at the final one), poisoning dead lanes to +inf there.  Outputs are
    identical to the per-step-checked scalar ``dtw`` (frontier minima are
    monotone), but the decision *points* match the kernel exactly, which is
    what keeps the two bit-comparable at abandon boundaries.
    """
    values, _ = _band_blocked_scan(a, b, w, cutoff, row_block)
    return values


@functools.partial(jax.jit, static_argnames=("w", "row_block"))
def dtw_band_death_blocks(
    a: Array,
    b: Array,
    w: int | None = None,
    cutoff: Array | None = None,
    *,
    row_block: int | None = None,
) -> Array:
    """(P,) index of the first row block whose boundary check abandons each
    lane (``n_blocks - 1`` for lanes that never abandon).

    The host-side mirror of the Pallas kernel's liveness schedule
    (kernels/dtw_band.py): a pair tile executes row blocks until *every*
    lane in it is dead, so a tile's last executed block is the max death
    block over its lanes.  ``tile_skip_rate`` turns these per-lane death
    blocks into the fraction of (tile, block) grid cells the early-exit
    grid skips for a given pair packing — the scheduler observability
    metric BENCH_kernels.json tracks for the bound-ordered vs unsorted
    verification schedules.
    """
    _, death = _band_blocked_scan(a, b, w, cutoff, row_block)
    return death


def tile_skip_rate(death_blocks, n_blocks: int, tile_p: int) -> float:
    """Fraction of (pair_tile, row_block) grid cells the early-exit grid
    skips, given per-lane death blocks in *packed* order.

    A tile runs blocks ``0..max(death_blocks over its lanes)`` and skips
    the rest; pad lanes (short final tile) die at block 0 like the
    kernel's -inf-cutoff padding, so they never hold a tile open.
    """
    import numpy as np

    death = np.asarray(death_blocks)
    pad = (-death.shape[0]) % tile_p
    if pad:
        death = np.concatenate([death, np.zeros(pad, death.dtype)])
    last = death.reshape(-1, tile_p).max(axis=1)
    n_tiles = last.shape[0]
    skipped = (n_blocks - 1 - last).sum()
    return float(skipped) / float(n_tiles * n_blocks)


@functools.partial(jax.jit, static_argnames=("w",))
def dtw_batch(a: Array, b: Array, w: int | None = None) -> Array:
    """Batched ``DTW_w`` over leading axes: ``(..., L) x (..., L) -> (...)``."""
    fn = dtw
    for _ in range(max(a.ndim, b.ndim) - 1):
        fn = jax.vmap(fn, in_axes=(0, 0, None))
    return fn(a, b, w)


def dtw_pairs(q: Array, c: Array, w: int | None = None) -> Array:
    """All-pairs ``DTW_w``: ``(Q, L) x (C, L) -> (Q, C)``.

    This is the expensive verification step the lower-bound cascade exists to
    avoid; the engine only calls it on cascade survivors.
    """
    per_q = jax.vmap(dtw, in_axes=(None, 0, None))     # (C,)
    return jax.vmap(per_q, in_axes=(0, None, None))(q, c, w)


def cost_matrix(a: Array, b: Array, w: int | None = None) -> Array:
    """Full DP matrix ``D`` (O(L^2) memory) — debugging / figures only."""
    L = a.shape[-1]
    if w is None or w >= L:
        w = L
    delta = (a[:, None] - b[None, :]) ** 2
    band = jnp.abs(jnp.arange(L)[:, None] - jnp.arange(L)[None, :]) <= w
    delta = jnp.where(band, delta, _INF)

    def row_step(prev_row, xs):
        drow, i = xs

        def col_step(left_val, xs2):
            dij, up, diag_ = xs2
            best = jnp.minimum(jnp.minimum(left_val, up), diag_)
            val = dij + best
            return val, val

        diag_prev = jnp.concatenate(
            [jnp.where(i == 0, 0.0, _INF)[None], prev_row[:-1]]
        )
        _, row = lax.scan(col_step, _INF, (drow, prev_row, diag_prev))
        return row, row

    init = jnp.full((L,), _INF)
    _, rows = lax.scan(row_step, init, (delta, jnp.arange(L)))
    return rows


def dtw_envelope_bound_gap(a: Array, b: Array, lb: Array, w: int | None = None) -> Array:
    """Tightness ``lb / DTW_w(a, b)`` (paper Eq. 15) for diagnostics."""
    d = dtw(a, b, w)
    return jnp.where(d > 0, lb / d, 1.0)
