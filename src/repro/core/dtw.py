"""Banded (Sakoe-Chiba) Dynamic Time Warping in pure JAX.

Paper Eqs. 1-2 with the warping-window constraint ``|i - j| <= w``
(SS II-A).  We minimise ``D(L, L)`` directly — squared-cost, no sqrt.

TPU adaptation (DESIGN.md SS3): the DP recurrence has an intra-row sequential
dependency (``D(i, j)`` needs ``D(i, j-1)``), so rows cannot be vectorised.
Cells on one *anti-diagonal* ``d = i + j`` depend only on diagonals ``d-1``
and ``d-2``, so we scan over the ``2L - 1`` anti-diagonals.

Band-packed layout (this is what makes work O(L*W), not O(L^2)): a cell is
addressed by its anti-diagonal ``d`` and its *diagonal offset*
``k = i - j + w`` in ``[0, 2w]``.  The state per diagonal is a dense
``Wb = 2w + 1`` vector instead of a length-``L`` one, and the recurrence is
pure shifts in ``k``:

    S_d[k] = cost(i, j) + min(S_{d-1}[k-1], S_{d-1}[k+1], S_{d-2}[k])

with ``i = (d + k - w) / 2`` (cells exist only when ``d + k - w`` is even —
half the lanes idle, which still wins for ``w << L``).  The cost gathers
``a[(d+k-w)//2]`` / ``b[(d-k+w)//2]`` — contiguous slices of the
*2x-duplicated* series ``A2[t] = a[t // 2]`` (and the flipped duplicate of
``b``), so every step is two ``dynamic_slice`` calls, no gathers.

Early abandon (PrunedDTW-style, arXiv:2102.05221): every warping path
crosses anti-diagonals ``d`` or ``d-1``, and path prefixes only grow, so
``min(S_d, S_{d-1})`` lower-bounds the final DTW.  When a ``cutoff`` is
given and that frontier minimum exceeds it, the state is poisoned to +inf
and the call returns +inf — the caller learns "distance > cutoff" without
paying for the rest of the matrix.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

_INF = jnp.inf


@functools.partial(jax.jit, static_argnames=("w",))
def dtw(a: Array, b: Array, w: int | None = None, cutoff=None) -> Array:
    """``DTW_w(a, b)`` for two equal-length 1-D series (squared cost).

    Args:
      a, b: ``(L,)`` series.
      w: Sakoe-Chiba half-width; ``None`` or ``>= L`` means unconstrained.
         ``w == 0`` is the squared Euclidean distance.
      cutoff: optional scalar early-abandon threshold.  Whenever the true
        distance is strictly below ``cutoff`` the result is exact; otherwise
        the result is ``>= cutoff`` (usually +inf — the lane abandons as
        soon as the frontier minimum proves the cutoff unreachable).

    Returns:
      Scalar ``D(L, L)`` (or +inf on abandon).
    """
    L = a.shape[-1]
    if w is None or w >= L:
        w = L
    wb = min(w, L - 1)                 # |i - j| <= L - 1 always holds
    Wb = 2 * wb + 1
    dt = a.dtype
    if cutoff is None:
        cutoff = jnp.asarray(_INF, dt)
    # 2x-duplicated series, shifted so slice starts stay non-negative:
    #   a2p[wb + t] = a[t // 2]     b2p[wb + t] = b[(2L - 1 - t) // 2]
    pad_len = 2 * L + Wb + wb
    a2 = jnp.repeat(a, 2, axis=-1)
    b2f = jnp.flip(jnp.repeat(b, 2, axis=-1), axis=-1)
    a2p = jnp.zeros((pad_len,), dt).at[wb:wb + 2 * L].set(a2)
    b2p = jnp.zeros((pad_len,), dt).at[wb:wb + 2 * L].set(b2f)
    kk = jnp.arange(Wb)

    def step(carry, d):
        d1, d2 = carry                                   # S_{d-1}, S_{d-2}
        a_at = lax.dynamic_slice(a2p, (d,), (Wb,))       # a[(d + k - wb)//2]
        b_at = lax.dynamic_slice(b2p, (2 * L - 1 - d,), (Wb,))
        cost = (a_at - b_at) ** 2
        inf1 = jnp.full((1,), _INF, dt)
        dep_l = jnp.concatenate([inf1, d1[:-1]])         # S_{d-1}[k-1]
        dep_r = jnp.concatenate([d1[1:], inf1])          # S_{d-1}[k+1]
        best = jnp.minimum(jnp.minimum(dep_l, dep_r), d2)
        origin = (d == 0) & (kk == wb)
        nd = cost + jnp.where(origin, 0.0, best)
        t = d + kk - wb                                  # 2i
        s = d - kk + wb                                  # 2j
        valid = ((t & 1) == 0) & (t >= 0) & (t <= 2 * L - 2) \
            & (s >= 0) & (s <= 2 * L - 2)
        nd = jnp.where(valid, nd, _INF)
        # every path crosses diagonal d or d-1 -> frontier min is a LB
        dead = jnp.min(jnp.minimum(nd, d1)) > cutoff
        nd = jnp.where(dead, _INF, nd)
        d1 = jnp.where(dead, _INF, d1)
        return (nd, d1), None

    init = (jnp.full((Wb,), _INF, dt), jnp.full((Wb,), _INF, dt))
    (dlast, _), _ = lax.scan(step, init, jnp.arange(2 * L - 1))
    return dlast[wb]


@functools.partial(jax.jit, static_argnames=("w",))
def dtw_batch(a: Array, b: Array, w: int | None = None) -> Array:
    """Batched ``DTW_w`` over leading axes: ``(..., L) x (..., L) -> (...)``."""
    fn = dtw
    for _ in range(max(a.ndim, b.ndim) - 1):
        fn = jax.vmap(fn, in_axes=(0, 0, None))
    return fn(a, b, w)


def dtw_pairs(q: Array, c: Array, w: int | None = None) -> Array:
    """All-pairs ``DTW_w``: ``(Q, L) x (C, L) -> (Q, C)``.

    This is the expensive verification step the lower-bound cascade exists to
    avoid; the engine only calls it on cascade survivors.
    """
    per_q = jax.vmap(dtw, in_axes=(None, 0, None))     # (C,)
    return jax.vmap(per_q, in_axes=(0, None, None))(q, c, w)


def cost_matrix(a: Array, b: Array, w: int | None = None) -> Array:
    """Full DP matrix ``D`` (O(L^2) memory) — debugging / figures only."""
    L = a.shape[-1]
    if w is None or w >= L:
        w = L
    delta = (a[:, None] - b[None, :]) ** 2
    band = jnp.abs(jnp.arange(L)[:, None] - jnp.arange(L)[None, :]) <= w
    delta = jnp.where(band, delta, _INF)

    def row_step(prev_row, xs):
        drow, i = xs

        def col_step(left_val, xs2):
            dij, up, diag_ = xs2
            best = jnp.minimum(jnp.minimum(left_val, up), diag_)
            val = dij + best
            return val, val

        diag_prev = jnp.concatenate(
            [jnp.where(i == 0, 0.0, _INF)[None], prev_row[:-1]]
        )
        _, row = lax.scan(col_step, _INF, (drow, prev_row, diag_prev))
        return row, row

    init = jnp.full((L,), _INF)
    _, rows = lax.scan(row_step, init, (delta, jnp.arange(L)))
    return rows


def dtw_envelope_bound_gap(a: Array, b: Array, lb: Array, w: int | None = None) -> Array:
    """Tightness ``lb / DTW_w(a, b)`` (paper Eq. 15) for diagnostics."""
    d = dtw(a, b, w)
    return jnp.where(d > 0, lb / d, 1.0)
