"""Sakoe-Chiba window envelopes (paper Eqs. 5-6).

``U_i = max_{|j - i| <= w} B_j`` and ``L_i = min_{|j - i| <= w} B_j``.

TPU adaptation (DESIGN.md SS3): Lemire's amortised-O(L) streaming min/max is a
data-dependent deque algorithm — it does not vectorise and would serialise the
VPU.  We instead use *prefix-doubling* sliding-window reductions: O(L log W)
dense shifted-max operations, every one of which is a full-width vector op.
log2(W) <= 19 for every shape in this repo, and each step is ~1 cycle/lane, so
this wins by orders of magnitude on SIMD hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG = -jnp.inf
_POS = jnp.inf


def _shift_left(x: Array, s: int, fill: float) -> Array:
    """``y[..., i] = x[..., i + s]``, positions past the end filled."""
    if s == 0:
        return x
    pad = jnp.full(x.shape[:-1] + (s,), fill, dtype=x.dtype)
    return jnp.concatenate([x[..., s:], pad], axis=-1)


def sliding_reduce(x: Array, k: int, op, fill: float) -> Array:
    """``y[..., i] = op-reduction of x[..., i : i + k]`` (clipped at the end).

    Prefix-doubling: build power-of-two windows by repeated shifted-op, then
    one final combine for the residual.  O(log k) vector ops.
    """
    if k <= 1:
        return x
    m = x
    p = 1
    while p * 2 <= k:
        m = op(m, _shift_left(m, p, fill))
        p *= 2
    if p < k:
        # union of [i, i+p) and [i+k-p, i+k) covers [i, i+k) since k - p <= p
        m = op(m, _shift_left(m, k - p, fill))
    return m


@functools.partial(jax.jit, static_argnames=("w",))
def envelope(b: Array, w: int) -> tuple[Array, Array]:
    """Upper/lower envelopes of ``b`` for window ``w`` (paper Eqs. 5-6).

    Args:
      b: ``(..., L)`` series (batched along leading axes).
      w: Sakoe-Chiba window half-width, ``0 <= w``.

    Returns:
      ``(upper, lower)`` of the same shape as ``b``.
    """
    if w == 0:
        return b, b
    L = b.shape[-1]
    k = 2 * w + 1
    pad = [(0, 0)] * (b.ndim - 1) + [(w, 0)]
    bu = jnp.pad(b, pad, constant_values=_NEG)
    bl = jnp.pad(b, pad, constant_values=_POS)
    u = sliding_reduce(bu, k, jnp.maximum, _NEG)[..., :L]
    lo = sliding_reduce(bl, k, jnp.minimum, _POS)[..., :L]
    return u, lo


def envelope_naive(b: Array, w: int) -> tuple[Array, Array]:
    """O(L*W) reference envelope via explicit window gathers (oracle)."""
    L = b.shape[-1]
    idx = jnp.arange(L)[:, None] + jnp.arange(-w, w + 1)[None, :]
    valid = (idx >= 0) & (idx < L)
    idx = jnp.clip(idx, 0, L - 1)
    vals = b[..., idx]  # (..., L, 2w+1)
    u = jnp.max(jnp.where(valid, vals, _NEG), axis=-1)
    lo = jnp.min(jnp.where(valid, vals, _POS), axis=-1)
    return u, lo
