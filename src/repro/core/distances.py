"""Pointwise and pairwise distance primitives.

The paper (SS II-A) uses the squared L2 norm as the per-link cost
``delta(a, b) = (a - b)^2`` and minimises ``D(L, L)`` directly (no square
root).  Everything in this package follows that convention: DTW values and
lower bounds are *sums of squared differences*.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def delta(a: Array, b: Array) -> Array:
    """Per-link cost ``(a - b)^2`` (paper Eq. 1/2 convention)."""
    d = a - b
    return d * d


def znorm(x: Array, axis: int = -1, eps: float = 1e-8) -> Array:
    """Z-normalise a series along ``axis`` (UCR convention)."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    sd = jnp.std(x, axis=axis, keepdims=True)
    return (x - mu) / (sd + eps)


def squared_euclidean(a: Array, b: Array) -> Array:
    """Squared Euclidean distance between two equal-length series.

    This equals ``DTW_0(a, b)`` (paper SS II-A: W=0 is the Euclidean
    distance), and is the cheapest exact-DTW special case in the cascade.
    """
    return jnp.sum(delta(a, b), axis=-1)


def squared_euclidean_matrix(q: Array, c: Array) -> Array:
    """All-pairs squared Euclidean distances via the MXU-friendly
    ``|q|^2 + |c|^2 - 2 q.c^T`` factorisation.

    Args:
      q: ``(Q, L)`` query series.
      c: ``(C, L)`` candidate series.

    Returns:
      ``(Q, C)`` matrix of squared distances.  This is the one part of the
      lower-bound cascade that maps onto the MXU (see DESIGN.md SS3) — the
      clamped envelope bounds are elementwise and run on the VPU.
    """
    qq = jnp.sum(q * q, axis=-1)[:, None]
    cc = jnp.sum(c * c, axis=-1)[None, :]
    qc = q @ c.T
    return jnp.maximum(qq + cc - 2.0 * qc, 0.0)
