"""DTW lower bounds: LB_KIM, LB_YI, LB_KEOGH, LB_IMPROVED, LB_NEW and the
paper's contribution LB_ENHANCED^V (Tan, Petitjean & Webb 2018).

Conventions (match SS II-A of the paper):
  * per-link cost ``delta(a, b) = (a - b)^2`` — all bounds lower-bound the
    *squared-cost* ``D(L, L)``, the quantity NN-DTW compares.
  * ``w`` is the Sakoe-Chiba half-width, ``0 <= w <= L``; every bound below
    is valid for ``DTW_w`` for any ``w`` (a constrained path set can only
    raise the DTW value).
  * All series are 1-D ``(L,)`` in the per-pair API; ``*_matrix`` variants
    compute ``(Q, C)`` blocks for the batched cascade (DESIGN.md SS3).

All bounds are branch-free (clamped-difference algebra instead of the
paper's per-element ``if``), which is what makes them vectorise on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.distances import delta
from repro.core.envelopes import envelope

Array = jax.Array

_INF = jnp.inf


# ---------------------------------------------------------------------------
# LB_KIM (paper SS II-B.1, Eq. 3, with the paper's "sum of features" variant)
# ---------------------------------------------------------------------------

def _interior(idx: Array, L: int) -> Array:
    return (idx != 0) & (idx != L - 1)


def lb_kim(a: Array, b: Array) -> Array:
    """Provably-safe O(1)-feature Kim bound (cascade tier 0).

    ``delta(a_1, b_1) + delta(a_L, b_L) + max(t_max, t_min)`` where the
    max/min feature terms are only admitted when their witness index is
    interior (so the witnessed link is distinct from the boundary links),
    and we take the *max* of the two feature terms rather than the paper's
    sum, because a single link can witness both features at once (e.g. A's
    argmax aligned to B's argmin).  See tests/test_lower_bounds.py for the
    counterexample that breaks the naive sum.
    """
    L = a.shape[-1]
    res = delta(a[..., 0], b[..., 0]) + delta(a[..., -1], b[..., -1])
    amax, bmax = jnp.max(a, -1), jnp.max(b, -1)
    amin, bmin = jnp.min(a, -1), jnp.min(b, -1)
    # witness = the series whose extremum is more extreme
    ia = jnp.where(amax >= bmax, jnp.argmax(a, -1), jnp.argmax(b, -1))
    t_max = jnp.where(_interior(ia, L), delta(amax, bmax), 0.0)
    im = jnp.where(amin <= bmin, jnp.argmin(a, -1), jnp.argmin(b, -1))
    t_min = jnp.where(_interior(im, L), delta(amin, bmin), 0.0)
    return res + jnp.maximum(t_max, t_min)


def lb_kim_paper(a: Array, b: Array) -> Array:
    """The paper's experimental LB_KIM variant (SS IV): sum of the four
    features, dropping the max/min features when that point is first/last.

    Soundness note: summing both extremum features relies on the witness
    links being distinct, which the first/last exclusion does not obviously
    guarantee.  We could not prove it, but an adversarial search (40k random
    pairs + exhaustive small value grids — see tests) found no violation,
    so it appears sound in practice.  The engine still uses the provably
    safe ``lb_kim`` (max instead of sum under possible collision).
    """
    L = a.shape[-1]
    res = delta(a[..., 0], b[..., 0]) + delta(a[..., -1], b[..., -1])
    ok_max = _interior(jnp.argmax(a, -1), L) & _interior(jnp.argmax(b, -1), L)
    ok_min = _interior(jnp.argmin(a, -1), L) & _interior(jnp.argmin(b, -1), L)
    res += jnp.where(ok_max, delta(jnp.max(a, -1), jnp.max(b, -1)), 0.0)
    res += jnp.where(ok_min, delta(jnp.min(a, -1), jnp.min(b, -1)), 0.0)
    return res


# ---------------------------------------------------------------------------
# LB_YI (paper SS II-B.2, Eq. 4)
# ---------------------------------------------------------------------------

def lb_yi(a: Array, b: Array) -> Array:
    bmax = jnp.max(b, -1, keepdims=True)
    bmin = jnp.min(b, -1, keepdims=True)
    over = jnp.maximum(a - bmax, 0.0)
    under = jnp.maximum(bmin - a, 0.0)
    return jnp.sum(over * over + under * under, axis=-1)


# ---------------------------------------------------------------------------
# LB_KEOGH (paper SS II-B.3, Eqs. 5-7)
# ---------------------------------------------------------------------------

def lb_keogh_env(a: Array, u: Array, lo: Array) -> Array:
    """LB_KEOGH given the candidate's precomputed envelope ``(u, lo)``."""
    over = jnp.maximum(a - u, 0.0)
    under = jnp.maximum(lo - a, 0.0)
    return jnp.sum(over * over + under * under, axis=-1)


@functools.partial(jax.jit, static_argnames=("w",))
def lb_keogh(a: Array, b: Array, w: int) -> Array:
    u, lo = envelope(b, w)
    return lb_keogh_env(a, u, lo)


def lb_keogh_matrix(q: Array, u: Array, lo: Array) -> Array:
    """``(Q, L) x (C, L)-envelopes -> (Q, C)`` Keogh block (VPU-bound)."""
    over = jnp.maximum(q[:, None, :] - u[None, :, :], 0.0)
    under = jnp.maximum(lo[None, :, :] - q[:, None, :], 0.0)
    return jnp.sum(over * over + under * under, axis=-1)


# ---------------------------------------------------------------------------
# LB_IMPROVED (paper SS II-B.4, Eqs. 8-9)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("w",))
def lb_improved(a: Array, b: Array, w: int) -> Array:
    u, lo = envelope(b, w)
    first = lb_keogh_env(a, u, lo)
    a_proj = jnp.clip(a, lo, u)                       # Eq. 8
    up, lp = envelope(a_proj, w)
    second = lb_keogh_env(b, up, lp)
    return first + second


# ---------------------------------------------------------------------------
# LB_NEW (paper SS II-B.5, Eq. 10)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("w",))
def lb_new(a: Array, b: Array, w: int) -> Array:
    """Boundary terms + exact windowed point-set minima for interior i.

    O(L*W) as a dense gather+reduce — on the VPU this beats the paper's
    O(L log W) tree lookups (data-dependent) by a wide margin.
    """
    L = a.shape[-1]
    w = min(w, L)
    res = delta(a[0], b[0]) + delta(a[-1], b[-1])
    ii = jnp.arange(L)[:, None]
    off = jnp.arange(-w, w + 1)[None, :]
    jj = ii + off
    valid = (jj >= 0) & (jj < L)
    vals = b[jnp.clip(jj, 0, L - 1)]                  # (L, 2w+1)
    d = delta(a[:, None], vals)
    d = jnp.where(valid, d, _INF)
    per_i = jnp.min(d, axis=-1)                       # (L,)
    interior = jnp.sum(per_i[1:-1])
    return res + interior


# ---------------------------------------------------------------------------
# LB_ENHANCED^V (the paper's contribution: SS III, Eq. 14 / Algorithm 1)
# ---------------------------------------------------------------------------

def _n_bands(L: int, w: int, v: int) -> int:
    """Algorithm 1 line 2: number of left/right elastic bands to use."""
    return max(0, min(L // 2, w, v))


def _band_minima(a: Array, b: Array, nb: int) -> Array:
    """Sum of per-band minima for the ``nb`` leftmost left bands and ``nb``
    rightmost right bands (paper Eqs. 11-12).

    Band ``i < nb <= w`` is L-shaped: cells ``delta(a_j, b_i)`` and
    ``delta(a_i, b_k)`` for ``j, k in [0, i]`` (left; window clamp is at the
    series start because ``i < w``), mirrored for the right end.  Arm width
    is ``i + 1 <= nb``, so the whole gather is an ``(nb, nb)`` block — this
    smallness is exactly why the bands are tight *and* cheap (SS III).
    """
    if nb == 0:
        return jnp.zeros(a.shape[:-1], a.dtype)
    L = a.shape[-1]
    i = jnp.arange(nb)[:, None]                       # band index
    t = jnp.arange(nb)[None, :]                       # offset along the arm
    mask = t <= i
    jl = jnp.clip(i - t, 0, L - 1)                    # left-band arm indices
    left1 = delta(_take(a, jl), _take(b, i))
    left2 = delta(_take(a, i), _take(b, jl))
    left = jnp.where(mask, jnp.minimum(left1, left2), _INF)
    jr = jnp.clip((L - 1 - i) + t, 0, L - 1)          # right-band arm indices
    ir = L - 1 - i
    right1 = delta(_take(a, jr), _take(b, ir))
    right2 = delta(_take(a, ir), _take(b, jr))
    right = jnp.where(mask, jnp.minimum(right1, right2), _INF)
    return jnp.sum(jnp.min(left, axis=-1), axis=-1) + jnp.sum(
        jnp.min(right, axis=-1), axis=-1
    )


def _take(x: Array, idx: Array) -> Array:
    """Gather along the last axis with broadcasting-friendly semantics."""
    return jnp.take(x, idx, axis=-1) if x.ndim == 1 else x[..., idx]


@functools.partial(jax.jit, static_argnames=("w", "v"))
def lb_enhanced_bands(a: Array, b: Array, w: int, v: int) -> Array:
    """Bands-only partial bound — Algorithm 1 lines 1-11.

    This is itself a valid lower bound and forms its own cascade tier: the
    paper's early-abandon test (line 12) becomes tier-level batch compaction
    on TPU (DESIGN.md SS3).
    """
    L = a.shape[-1]
    return _band_minima(a, b, _n_bands(L, w, v))


@functools.partial(jax.jit, static_argnames=("w", "v"))
def lb_enhanced(a: Array, b: Array, w: int, v: int) -> Array:
    """LB_ENHANCED^V (Eq. 14 with Algorithm 1's ``n_bands`` clamp)."""
    u, lo = envelope(b, w)
    return lb_enhanced_env(a, b, u, lo, w, v)


def lb_enhanced_env(a: Array, b: Array, u: Array, lo: Array, w: int, v: int) -> Array:
    """LB_ENHANCED^V with a precomputed candidate envelope."""
    L = a.shape[-1]
    nb = _n_bands(L, w, v)
    bands = _band_minima(a, b, nb)
    # Keogh bridge over i in [nb, L - nb)
    sl = slice(nb, L - nb)
    over = jnp.maximum(a[..., sl] - u[..., sl], 0.0)
    under = jnp.maximum(lo[..., sl] - a[..., sl], 0.0)
    bridge = jnp.sum(over * over + under * under, axis=-1)
    return bands + bridge


def lb_enhanced_matrix(
    q: Array, c: Array, u: Array, lo: Array, w: int, v: int
) -> Array:
    """``(Q, L) x (C, L) -> (Q, C)`` LB_ENHANCED block for the cascade.

    Bands cost O(Q*C*nb^2) on an ``(nb, nb)`` gather block; the bridge is the
    O(Q*C*L) Keogh term.  Callers tile Q and C so the block fits VMEM; the
    Pallas kernel (kernels/lb_enhanced.py) fuses both parts.
    """
    L = q.shape[-1]
    nb = _n_bands(L, w, v)
    qe = q[:, None, :]                                # (Q, 1, L)
    ce = c[None, :, :]                                # (1, C, L)
    bands = _band_minima_matrix(qe, ce, nb)
    sl = slice(nb, L - nb)
    over = jnp.maximum(qe[..., sl] - u[None, :, sl], 0.0)
    under = jnp.maximum(lo[None, :, sl] - qe[..., sl], 0.0)
    bridge = jnp.sum(over * over + under * under, axis=-1)
    return bands + bridge


def _band_minima_matrix(qe: Array, ce: Array, nb: int) -> Array:
    """Broadcasted version of ``_band_minima`` for (Q, 1, L) x (1, C, L)."""
    if nb == 0:
        shape = jnp.broadcast_shapes(qe.shape[:-1], ce.shape[:-1])
        return jnp.zeros(shape, qe.dtype)
    L = qe.shape[-1]
    i = jnp.arange(nb)[:, None]
    t = jnp.arange(nb)[None, :]
    mask = t <= i
    jl = jnp.clip(i - t, 0, L - 1)
    left = jnp.minimum(
        delta(qe[..., jl], ce[..., i]), delta(qe[..., i], ce[..., jl])
    )
    left = jnp.where(mask, left, _INF)
    ir = L - 1 - i
    jr = jnp.clip(ir + t, 0, L - 1)
    right = jnp.minimum(
        delta(qe[..., jr], ce[..., ir]), delta(qe[..., ir], ce[..., jr])
    )
    right = jnp.where(mask, right, _INF)
    return jnp.sum(jnp.min(left, -1), -1) + jnp.sum(jnp.min(right, -1), -1)


# ---------------------------------------------------------------------------
# Registry (benchmarks & engine tiers select bounds by name)
# ---------------------------------------------------------------------------

def get_bound(name: str, w: int, v: int = 4):
    """Return a ``fn(a, b) -> scalar`` closure for a named bound."""
    name = name.lower()
    if name == "lb_kim":
        return lb_kim
    if name == "lb_kim_paper":
        return lb_kim_paper
    if name == "lb_yi":
        return lb_yi
    if name == "lb_keogh":
        return lambda a, b: lb_keogh(a, b, w)
    if name == "lb_improved":
        return lambda a, b: lb_improved(a, b, w)
    if name == "lb_new":
        return lambda a, b: lb_new(a, b, w)
    if name.startswith("lb_enhanced"):
        vv = int(name.rsplit("_", 1)[-1]) if name[-1].isdigit() else v
        return lambda a, b: lb_enhanced(a, b, w, vv)
    raise ValueError(f"unknown lower bound: {name!r}")


BOUND_NAMES = (
    "lb_kim",
    "lb_keogh",
    "lb_improved",
    "lb_new",
    "lb_enhanced_1",
    "lb_enhanced_2",
    "lb_enhanced_3",
    "lb_enhanced_4",
)
