"""Distribution substrate: sharding rules, compression, elasticity."""

from repro.distributed.compression import CompressionConfig, compress_grads
from repro.distributed.elastic import Heartbeat, MeshPlan, plan_mesh
from repro.distributed.sharding import (
    AxisRules,
    activation_spec,
    batch_specs,
    cache_shardings,
    param_shardings,
    param_spec,
)

__all__ = [
    "AxisRules",
    "CompressionConfig",
    "Heartbeat",
    "MeshPlan",
    "activation_spec",
    "batch_specs",
    "cache_shardings",
    "compress_grads",
    "param_shardings",
    "param_spec",
    "plan_mesh",
]
