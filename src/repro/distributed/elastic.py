"""Elastic scaling + straggler mitigation policy.

``plan_mesh`` re-derives a (data, model)[, pod] mesh for whatever device
count survives a failure; together with checkpoint.restore's
reshard-on-restore this is the restart path: lose a host -> relaunch with
the surviving device set -> same checkpoint, new mesh, training continues.
The model axis is kept at the largest divisor <= preferred_tp that divides
the device count, because TP size changes activation sharding but never
numerics.

``Heartbeat`` is the straggler/liveness primitive the launcher monitors:
each host touches its file every step; the monitor evicts hosts whose
heartbeat age exceeds the deadline (on CPU we exercise the file protocol,
not the eviction RPC).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]


def plan_mesh(
    n_devices: int,
    *,
    preferred_tp: int = 16,
    pods: int = 1,
) -> MeshPlan:
    """Choose mesh factors for an arbitrary surviving device count."""
    per_pod = n_devices // pods
    tp = preferred_tp
    while tp > 1 and per_pod % tp:
        tp //= 2
    data = per_pod // tp
    if pods > 1:
        return MeshPlan((pods, data, tp), ("pod", "data", "model"))
    return MeshPlan((data, tp), ("data", "model"))


@dataclasses.dataclass
class Heartbeat:
    """Per-host liveness file; the launcher monitors heartbeat age."""

    path: str
    host_id: int = 0

    def beat(self, step: int) -> None:
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host_id, "step": step, "t": time.time()}, f)
        os.replace(tmp, self.path)

    def age(self) -> float | None:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["t"]
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def is_straggler(self, deadline_s: float) -> bool:
        age = self.age()
        return age is None or age > deadline_s
