"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scaled quantisation applied to gradients before the
cross-replica reduction, with local error feedback so the quantisation
noise is unbiased over steps (1-bit-Adam/EF-SGD family).  On a real pod the
quantised tensors are what crosses the DCI between pods — a 4x wire saving
on the inter-pod all-reduce; error feedback keeps convergence intact.

The hook is numerically honest on CPU too (tests assert the error-feedback
invariant: compressed + error == original).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8
    error_feedback: bool = True
    min_size: int = 4096    # don't quantise small leaves (norms, biases)


def _quantize(g: Array, bits: int) -> Array:
    """Fake-quantise to ``bits`` with per-tensor symmetric scale."""
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / qmax
    q = jnp.round(g / scale)
    q = jnp.clip(q, -qmax, qmax)
    return q * scale


def compress_grads(
    grads: Any, err: Any | None, cfg: CompressionConfig
) -> tuple[Any, Any | None]:
    """Returns (compressed grads, new error state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32)
        if g.size < cfg.min_size:
            return g32, jnp.zeros_like(g32)
        target = g32 + (e if e is not None else 0.0)
        q = _quantize(target, cfg.bits)
        return q, target - q

    if err is None:
        outs = jax.tree.map(lambda g: one(g, None), grads)
    else:
        outs = jax.tree.map(one, grads, err)
    flat, tdef = jax.tree.flatten(outs, is_leaf=lambda x: isinstance(x, tuple))
    comp = jax.tree.unflatten(tdef, [f[0] for f in flat])
    new_err = jax.tree.unflatten(tdef, [f[1] for f in flat])
    return comp, (new_err if cfg.error_feedback else None)
