"""Per-architecture sharding rules: param/activation/cache PartitionSpecs.

Parallelism mapping (DESIGN.md SS6):
  * ``model`` axis: tensor parallelism (Megatron column/row) for attention
    and MLPs, expert parallelism for MoE, channel parallelism for Mamba
    (zero-collective inside the recurrence), vocab parallelism for the
    embedding/head where divisible;
  * ``data`` axis: batch DP + FSDP-style parameter/optimizer sharding
    (gather-on-use is GSPMD's job once the at-rest spec says so);
  * ``pod`` axis (multi-pod): pure DP — gradients reduce hierarchically
    (reduce-scatter intra-pod over ICI, all-reduce inter-pod over DCI).

Rules are name-based over the param tree; anything unknown stays
replicated, which is always correct and shows up as memory in the dry-run
(i.e. loudly).  kv/vocab axes fall back to replication when not divisible
by the tp size (e.g. gemma2 kv=4, hubert vocab=504).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ArchConfig, ShapeConfig


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (0.4.x spells it
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``)."""
    if hasattr(jax, "shard_map"):                      # jax >= 0.6
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map   # jax 0.4.x
    return shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AxisRules:
    fsdp: str | None = "data"
    tp: str | None = "model"
    ep: str | None = "model"
    dp: tuple[str, ...] = ("data",)     # batch axes (('pod','data') multi-pod)

    @staticmethod
    def for_mesh(mesh: Mesh) -> "AxisRules":
        if "pod" in mesh.axis_names:
            return AxisRules(dp=("pod", "data"))
        return AxisRules()


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
    return out


def _axis_size(mesh: Mesh, name: str | None) -> int:
    if name is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def param_spec(
    cfg: ArchConfig, mesh: Mesh, rules: AxisRules, path, leaf,
    *, serve: bool = False,
) -> P:
    """Param PartitionSpec.  ``serve=True`` switches to weight-stationary
    inference sharding (SSPerf hillclimb 2): weights live TP-sharded over
    ``model`` only and are *never* gathered — at decode the ``data`` axis
    carries batch, so FSDP-on-data weights would be all-gathered every
    step, costing more wire than the whole step's compute."""
    names = _path_names(path)
    name = names[-1]
    tp = rules.tp if _axis_size(mesh, rules.tp) > 1 else None
    fsdp = rules.fsdp if _axis_size(mesh, rules.fsdp) > 1 else None
    if serve:
        fsdp = None          # weight-stationary: no gather-on-use sharding
    ep = rules.ep if _axis_size(mesh, rules.ep) > 1 else None
    tp_size = _axis_size(mesh, rules.tp)
    kv_ok = cfg.n_kv_heads % max(tp_size, 1) == 0
    vocab_ok = cfg.vocab % max(tp_size, 1) == 0
    in_moe = "moe" in names

    if name in ("wq",):
        spec = (fsdp, tp)
    elif name in ("wk", "wv"):
        spec = (fsdp, tp if kv_ok else None)
    elif name in ("wi", "wg"):
        spec = (ep, fsdp, None) if in_moe else (fsdp, tp)
    elif name == "wo":
        spec = (ep, None, fsdp) if in_moe else (tp, fsdp)
    elif name == "in_proj":
        spec = (fsdp, tp)
    elif name == "out_proj":
        spec = (tp, fsdp)
    elif name == "x_proj":
        spec = (tp, None)
    elif name == "dt_proj":
        spec = (None, tp)
    elif name == "A_log":
        spec = (tp, None)
    elif name == "conv_w":
        spec = (None, tp)
    elif name in ("D", "dt_bias", "conv_b"):
        spec = (tp,)
    elif name == "router":
        spec = (fsdp, None)
    elif name == "embed":
        spec = (tp if vocab_ok else None, fsdp)
    elif name == "head":
        spec = (fsdp, tp if vocab_ok else None)
    elif name == "bq":
        spec = (tp,)
    elif name in ("bk", "bv"):
        spec = (tp if kv_ok else None,)
    else:  # norms and anything unrecognised: replicated
        spec = (None,) * leaf.ndim
    if leaf.ndim == len(spec) + 1:      # stacked scan leaf: leading repeat axis
        spec = (None,) + spec
    assert leaf.ndim == len(spec), (names, leaf.shape, spec)
    # drop specs on axes whose size does not divide the dimension
    fixed = []
    for dim, ax in zip(leaf.shape, spec):
        if ax is None:
            fixed.append(None)
        else:
            sz = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                sz *= _axis_size(mesh, a)
            fixed.append(ax if dim % sz == 0 else None)
    return P(*fixed)


def param_shardings(
    cfg: ArchConfig, mesh: Mesh, rules: AxisRules, params_tree: Any,
    *, serve: bool = False,
) -> Any:
    """NamedSharding pytree matching ``params_tree`` (arrays or shape structs)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, a: NamedSharding(
            mesh, param_spec(cfg, mesh, rules, p, a, serve=serve)
        ),
        params_tree,
    )


def batch_specs(
    cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, rules: AxisRules
) -> dict[str, P]:
    """PartitionSpecs for one input batch of the given shape cell."""
    dp_size = 1
    for a in rules.dp:
        dp_size *= _axis_size(mesh, a)
    b_ok = shape.global_batch % dp_size == 0 and shape.global_batch >= dp_size
    bspec = rules.dp if b_ok else None
    specs: dict[str, P] = {}
    if cfg.embed_inputs:
        specs["tokens"] = P(bspec, None)
    else:
        specs["frames"] = P(bspec, None, None)
    if shape.kind == "train":
        specs["labels"] = P(bspec, None)
    if cfg.vision_prefix:
        specs["vision_embeds"] = P(bspec, None, None)
        specs["positions"] = P(bspec, None, None)
    return specs


def cache_shardings(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: AxisRules,
    cache_tree: Any,
    *,
    batch: int,
) -> Any:
    """Cache specs: batch-shard KV when divisible, else shard the sequence
    axis over the data axes (long-context decode); SSM channels over tp."""
    dp_size = 1
    for a in rules.dp:
        dp_size *= _axis_size(mesh, a)
    b_ok = batch % dp_size == 0 and batch >= dp_size
    tp = rules.tp if _axis_size(mesh, rules.tp) > 1 else None
    tp_size = _axis_size(mesh, rules.tp)
    kv_ok = cfg.n_kv_heads % max(tp_size, 1) == 0
    din_ok = cfg.d_inner_ % max(tp_size, 1) == 0

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = leaf.ndim and "scan" in names
        if name in ("k", "v"):
            # decision table: batch over dp when divisible; kv heads over tp
            # when divisible, else the *sequence* axis takes the tp (and,
            # for batch=1 long-context, also the dp) shards — GSPMD handles
            # the partial-softmax combine (sequence-parallel attention).
            if b_ok and kv_ok:
                base = (rules.dp, None, tp, None)
            elif b_ok:
                base = (rules.dp, tp, None, None)
            elif kv_ok:
                base = (None, rules.dp, tp, None)
            else:
                base = (None, tuple(rules.dp) + ((tp,) if tp else ()), None, None)
        elif name == "h":
            base = ((rules.dp, tp if din_ok else None, None)
                    if b_ok else (None, tp if din_ok else None, None))
        elif name == "conv":
            base = ((rules.dp, None, tp if din_ok else None)
                    if b_ok else (None, None, tp if din_ok else None))
        else:
            base = (None,) * leaf.ndim
        if leaf.ndim == len(base) + 1:
            base = (None,) + base
        return NamedSharding(mesh, P(*base))

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def activation_spec(cfg: ArchConfig, rules: AxisRules, batch_ok: bool = True) -> P:
    """Residual-stream constraint: batch over dp; d_model over tp for the
    very wide archs (keeps the scan carry within HBM, DESIGN.md SS6)."""
    b = rules.dp if batch_ok else None
    d = rules.tp if cfg.d_model >= 8192 else None
    return P(b, None, d)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-constraint helper threaded through the model layers.

    ``con(x, roles...)`` applies ``with_sharding_constraint`` where each
    role is None, "dp" (batch axes) or "tp" (tensor axis); a role is
    silently dropped when the dimension is not divisible by the axis size,
    so the same model code serves every arch (gemma2's kv=4 heads, hubert's
    504-vocab head, long_500k's batch=1 all degrade to replication instead
    of erroring).  mesh=None makes every call a no-op (unit tests).
    """

    mesh: Mesh | None = None
    dp: tuple[str, ...] = ("data",)
    tp: str = "model"
    seq_shard: bool = False   # Megatron-SP: residual stream S over tp

    def _size(self, axes) -> int:
        n = 1
        for a in axes if isinstance(axes, tuple) else (axes,):
            n *= _axis_size(self.mesh, a)
        return n

    def con(self, x, *roles):
        if self.mesh is None:
            return x
        assert x.ndim == len(roles), (x.shape, roles)
        spec = []
        for dim, role in zip(x.shape, roles):
            if role == "sp":   # sequence-parallel residual (SSPerf A3)
                role = "tp" if self.seq_shard else None
            if role == "dp" and dim % max(self._size(self.dp), 1) == 0 and self._size(self.dp) > 1:
                spec.append(self.dp)
            elif role == "tp" and dim % max(self._size(self.tp), 1) == 0 and self._size(self.tp) > 1:
                spec.append(self.tp)
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )
