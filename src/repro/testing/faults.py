"""Deterministic fault injectors: prove every guard *trips*.

A guard that only ever passes is indistinguishable from a guard that
checks nothing — tests/test_guards.py pairs each injector here with the
guard that must catch it, so the guard subsystem's detection claims are
themselves tested (the same discipline as the strict-xfail that pinned
the shard_map miscompile).

Mechanics: ``inject(name, fn)`` installs ``fn`` into the
``search/guards.py`` ``_FAULT_HOOKS`` registry for the duration of a
``with`` block; production call sites consult the registry with a single
dict lookup *at trace time*, so outside the harness the seams cost
nothing and compile to nothing.  Hooks are pure jnp transforms of the
value flowing through the seam — they trace like any other op, so the
faults fire identically under ``jit`` and ``shard_map``.

The seams (see guards.py module docstring):

  ``tier_out``          (t, tier_name) -> t     bound-tier output
  ``compaction_cand``   (cand) -> cand          compaction's (Q, W) pick
  ``packed_rows``       (crows, urows, lrows) -> same   packed survivors
  ``dtw_out``           (d) -> d                kernels/ops.py DTW dispatch
  ``engine_count``      (seg) -> seg            engine per-round n_dtw inc
  ``allgather_topk``    (d_all) -> d_all        distributed top-k merge
  ``sketch_feats``      (sk_lo, sk_hi) -> same  build-time sketch quantiser

Everything is deterministic — fixed rows, fixed scales, no RNG — so a
tripped guard reproduces bit-for-bit.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Iterator

import jax.numpy as jnp
import numpy as np

from repro.search import guards as _guards


@contextlib.contextmanager
def inject(name: str, fn: Callable) -> Iterator[None]:
    """Install ``fn`` at seam ``name`` for the duration of the block.

    Teardown is guaranteed (``finally``), and nesting different seams
    composes; re-entering the *same* seam inside its own block raises —
    a silently shadowed injector would make a trip test vacuous.
    """
    if name in _guards._FAULT_HOOKS:
        raise RuntimeError(f"fault seam {name!r} already injected")
    _guards._FAULT_HOOKS[name] = fn
    try:
        yield
    finally:
        _guards._FAULT_HOOKS.pop(name, None)


# ---------------------------------------------------------------------------
# input corruption (plain data transforms — exercised via hygiene)
# ---------------------------------------------------------------------------


def corrupt_series(x, rows=(0,), cols=(0,), value: float = np.nan):
    """NaN/Inf-corrupt fixed positions of a (N, L) array (host-side).

    The hygiene injector: feed the result to ``build_index`` /
    ``nn_search`` and the boundary validation must reject it (or, with
    ``sanitize=True``, mask it and count it in the report).
    """
    arr = np.array(x, np.float32, copy=True)
    for r in rows:
        for c in cols:
            arr[r, c] = value
    return arr


def poison_envelopes(index, rows=(0,), value: float = np.nan):
    """Return a copy of a ``DTWIndex`` whose envelope rows are poisoned.

    Simulates precomputation corruption *past* the hygiene boundary
    (bit-rot, a bad checkpoint restore): the bands tiers consume the
    poisoned envelopes and emit non-finite bounds — the finite-value
    gate must contain them (count them, keep results exact).
    """
    import dataclasses

    rows = np.asarray(rows)
    upper = np.array(index.upper, np.float32, copy=True)
    lower = np.array(index.lower, np.float32, copy=True)
    upper[rows] = value
    lower[rows] = value
    return dataclasses.replace(
        index, upper=jnp.asarray(upper), lower=jnp.asarray(lower)
    )


# ---------------------------------------------------------------------------
# seam injectors (context managers)
# ---------------------------------------------------------------------------


def inadmissible_tier(tier: str = "bands", scale: float = 4.0,
                      shift: float = 1.0):
    """Make one bound tier *lie upward*: ``LB -> LB * scale + shift``.

    An inflated lower bound violates admissibility (LB <= DTW) — the
    cascade's seed spot-check or the engine's per-round check must trip,
    and the degradation rerun must fall back to the trusted default
    plan.  Only finite bounds are inflated (the -inf dead-slot identity
    stays put, so the fault is a *plausible* tier bug, not a shape
    error).
    """

    def hook(t, name):
        if name != tier:
            return t
        return jnp.where(jnp.isfinite(t), t * scale + shift, t)

    return inject("tier_out", hook)


def nonfinite_tier(tier: str = "bands", value: float = np.nan):
    """Replace one tier's output with NaN/Inf wholesale — the finite
    gate must count and contain every poisoned value."""

    def hook(t, name):
        return jnp.full_like(t, value) if name == tier else t

    return inject("tier_out", hook)


def drop_compaction_candidates(n_dup: int = 1):
    """Replay the shard_map miscompile *shape*: live candidates silently
    lost from the compaction pack.

    Overwrites the last ``n_dup`` selected candidate columns with the
    first column's candidate — the pack now contains duplicates, so
    ``n_dup`` real survivors were dropped without any error, exactly
    what the jax 0.4.x ``jit(shard_map(while))`` bug did downstream.
    The conservation guard's distinct-count must trip.  (Results stay
    exact — dropped survivors keep their valid cheap-tier bound — which
    is precisely why only a guard can see this fault.)
    """

    def hook(cand):
        dup = jnp.broadcast_to(cand[:, :1], (cand.shape[0], n_dup))
        return cand.at[:, -n_dup:].set(dup)

    return inject("compaction_cand", hook)


def corrupt_packed_rows(value: float = np.nan, rows: int = 1):
    """NaN/Inf-corrupt the packed survivor tiles feeding the pairwise
    tiers (the post-gather analogue of ``poison_envelopes``) — the
    finite gate on the pairwise tier outputs must contain it."""

    def hook(crows, urows, lrows):
        bad = jnp.full_like(crows[:rows], value)
        return (
            crows.at[:rows].set(bad),
            urows.at[:rows].set(bad),
            lrows.at[:rows].set(bad),
        )

    return inject("packed_rows", hook)


def corrupt_dtw(scale: float | None = 0.05, value: float | None = None):
    """Corrupt the Pallas DTW dispatch's outputs (kernels/ops.py seam).

    ``scale`` < 1 shrinks finite distances — verified values now sit
    *below* valid bounds, so the admissibility guard trips and the
    degradation fallback (reference brute force on the jnp kernels,
    which do not pass this seam) must restore bit-equality.  ``value``
    (e.g. NaN) overwrites finite outputs wholesale instead — the
    engine's finite gate counts and contains them, and because a +inf
    gate on a *verification* value may exclude a true neighbour, the
    NaN-DTW guard also trips the fallback.
    """

    def hook(d):
        fin = jnp.isfinite(d)
        if value is not None:
            return jnp.where(fin, jnp.full_like(d, value), d)
        return jnp.where(fin, d * scale, d)

    return inject("dtw_out", hook)


def miscount_verifications(delta: int = 1):
    """Perturb the engine's per-round ``n_dtw`` increment (add ``delta``
    to query 0's count each round) — the accounting guard's
    segment-sum-vs-mirror comparison must trip."""

    def hook(seg):
        return seg.at[0].add(delta)

    return inject("engine_count", hook)


def inward_quantiser(steps: int = 96):
    """Break the sketch quantiser's outward-rounding invariant.

    The tier-(-1) sketch bound is admissible *because* quantisation only
    ever widens the stored envelope (``ceil`` up, ``floor`` down —
    search/index.py).  This injector narrows it instead: the stored
    segment envelope pulls inward by ``steps`` int8 steps on both sides
    (clipped to the grid), the model of a quantiser bug that rounds
    toward zero or drops the headroom term.  Inverted envelopes
    (``lo > hi``) make the sketch bound *positive* for pairs whose true
    DTW is small, so the seed admissibility spot-check must trip on any
    store whose near-neighbour distances sit below the inflated bound,
    and the engine's degradation rerun (brute force on the jnp kernels —
    no sketch tier at all) must restore bit-equality.

    The seam lives in ``index.sketch_features`` — a *build-time* fault
    like ``poison_envelopes``: inject around ``build_index`` and the
    corrupted store persists for every later search.
    """

    def hook(sk_lo, sk_hi):
        lo = jnp.clip(sk_lo.astype(jnp.int32) + steps, -127, 127)
        hi = jnp.clip(sk_hi.astype(jnp.int32) - steps, -127, 127)
        return lo.astype(jnp.int8), hi.astype(jnp.int8)

    return inject("sketch_feats", hook)


def shard_dropout(shard: int = 0):
    """Simulate a dead shard in the distributed top-k merge: shard
    ``shard``'s all-gathered contribution comes back +inf (its
    candidates vanish from every merge).  The distributed echo check —
    each shard must find its own top-k intact in the gather — trips
    conservation on the dropped shard."""

    def hook(d_all):
        return d_all.at[shard].set(jnp.inf)

    return inject("allgather_topk", hook)
