"""Test-support machinery (fault injection) — never imported by the
production search stack; the stack only exposes the seams
(``search/guards.py:_FAULT_HOOKS``) this package populates."""
