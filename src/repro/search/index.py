"""Candidate-store index for lower-bounded NN-DTW search.

The index precomputes everything that depends only on the store and the
window ``w`` (paper SS II-B: envelopes are query-independent, so an index
amortises them across every query): the Sakoe-Chiba envelopes and the O(1)
Kim feature vector of every candidate.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.distances import znorm
from repro.kernels.ops import envelope_op

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DTWIndex:
    """Immutable candidate store + per-candidate precomputation.

    Attributes:
      series:  (N, L) candidate series (z-normalised if built with znorm).
      labels:  (N,) int labels (or -1s when unlabelled).
      upper:   (N, L) upper envelopes for window ``w``.
      lower:   (N, L) lower envelopes.
      kim:     (N, 4) [first, last, max, min] Kim features.
      kim_ok:  (N, 2) feature-admissibility flags [max interior, min interior].
      w:       static window the envelopes were built for.
    """

    series: Array
    labels: Array
    upper: Array
    lower: Array
    kim: Array
    kim_ok: Array
    w: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.series.shape[0]

    @property
    def length(self) -> int:
        return self.series.shape[1]


def kim_features(x: Array) -> tuple[Array, Array]:
    """Per-series Kim features + interior-witness flags (see lb_kim)."""
    L = x.shape[-1]
    first = x[..., 0]
    last = x[..., -1]
    mx = jnp.max(x, -1)
    mn = jnp.min(x, -1)
    imax = jnp.argmax(x, -1)
    imin = jnp.argmin(x, -1)
    feats = jnp.stack([first, last, mx, mn], axis=-1)
    ok = jnp.stack(
        [(imax != 0) & (imax != L - 1), (imin != 0) & (imin != L - 1)],
        axis=-1,
    )
    return feats, ok


def build_index(
    series: Array,
    w: int,
    labels: Array | None = None,
    *,
    normalize: bool = False,
    sanitize: bool = False,
    preflight: bool = False,
    calibrate: Any | None = None,
    calibrate_sample: int = 8,
) -> DTWIndex:
    """Build a ``DTWIndex`` for window ``w``.

    Input hygiene (concrete inputs; skipped under tracing like the
    calibration below): a store containing NaN/Inf raises — one poisoned
    value flows silently into envelopes, Kim features, and every bound
    otherwise — as does, with ``normalize=True``, a zero-variance series
    (z-norm maps it to all-zeros, which then matches every flat query at
    distance ~0).  ``sanitize=True`` masks bad values to the per-series
    finite mean, keeps flat series (znorm's epsilon maps them to zeros),
    and reports everything via a ``GuardWarning``
    (guards.validate_series).

    ``preflight`` runs ``guards.preflight_engine()`` — the single-device
    jitted-engine-vs-brute-force canary — before the store is returned,
    warning (once per process) if the compiled path is not exact on this
    jax install.  The distributed analogue lives in
    ``make_distributed_search`` (its preflight is on by default because
    the jax 0.4.x ``jit(shard_map(while))`` miscompile is a known,
    detectable failure).

    ``calibrate`` (an ``EngineConfig`` or ``CascadeConfig``) runs store-
    level plan calibration at build time: a ``calibrate_sample``-series
    sample of the store itself is searched leave-one-out through the
    instrumented tier pipeline and the planner's optimised plan is
    committed for this store/config (search/planner.py), so repeated-
    query serving starts warm — the first real query batch runs the
    committed plan instead of paying a calibration block.  The LOO
    exclusion keeps the measured threshold honest (a self-match would
    collapse ``tau`` to zero, the same argument as
    ``choose_survivor_budget``), and a LOO-calibrated plan is
    conservative for plain queries, so the committed decision serves
    both.  Calibration requires concrete (host) inputs; it is skipped
    for unstaged cascades.
    """
    series = jnp.asarray(series, jnp.float32)
    if not isinstance(series, jax.core.Tracer):
        from repro.search import guards as _guards

        series, _ = _guards.validate_series(
            series, name="series", sanitize=sanitize, check_flat=normalize,
        )
        if preflight:
            _guards.preflight_engine()
    if normalize:
        series = znorm(series)
    if labels is None:
        labels = jnp.full((series.shape[0],), -1, jnp.int32)
    u, lo = envelope_op(series, w)
    kim, kim_ok = kim_features(series)
    index = DTWIndex(
        series=series,
        labels=jnp.asarray(labels, jnp.int32),
        upper=u,
        lower=lo,
        kim=kim,
        kim_ok=kim_ok,
        w=w,
    )
    if calibrate is not None:
        from repro.search.planner import calibrate_plan, calibration_sample

        cascade = getattr(calibrate, "cascade", calibrate)
        k = int(getattr(calibrate, "k", 1))
        if cascade.staged and not isinstance(series, jax.core.Tracer):
            # strided store sample: class-ordered stores get every class
            # into the measurement (planner.calibration_sample)
            pick = calibration_sample(index.n, calibrate_sample)
            calibrate_plan(
                index.series[pick], index, cascade, k,
                exclude=jnp.asarray(pick, jnp.int32), sample=len(pick),
                pcfg=getattr(calibrate, "planner", None),
            )
    return index
