"""Candidate-store index for lower-bounded NN-DTW search.

The index precomputes everything that depends only on the store and the
window ``w`` (paper SS II-B: envelopes are query-independent, so an index
amortises them across every query): the Sakoe-Chiba envelopes and the O(1)
Kim feature vector of every candidate.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.distances import znorm
from repro.kernels.ops import envelope_op

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DTWIndex:
    """Immutable candidate store + per-candidate precomputation.

    Attributes:
      series:  (N, L) candidate series (z-normalised if built with znorm).
      labels:  (N,) int labels (or -1s when unlabelled).
      upper:   (N, L) upper envelopes for window ``w``.
      lower:   (N, L) lower envelopes.
      kim:     (N, 4) [first, last, max, min] Kim features.
      kim_ok:  (N, 2) feature-admissibility flags [max interior, min interior].
      w:       static window the envelopes were built for.
    """

    series: Array
    labels: Array
    upper: Array
    lower: Array
    kim: Array
    kim_ok: Array
    w: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.series.shape[0]

    @property
    def length(self) -> int:
        return self.series.shape[1]


def kim_features(x: Array) -> tuple[Array, Array]:
    """Per-series Kim features + interior-witness flags (see lb_kim)."""
    L = x.shape[-1]
    first = x[..., 0]
    last = x[..., -1]
    mx = jnp.max(x, -1)
    mn = jnp.min(x, -1)
    imax = jnp.argmax(x, -1)
    imin = jnp.argmin(x, -1)
    feats = jnp.stack([first, last, mx, mn], axis=-1)
    ok = jnp.stack(
        [(imax != 0) & (imax != L - 1), (imin != 0) & (imin != L - 1)],
        axis=-1,
    )
    return feats, ok


def build_index(
    series: Array,
    w: int,
    labels: Array | None = None,
    *,
    normalize: bool = False,
) -> DTWIndex:
    """Build a ``DTWIndex`` for window ``w``."""
    series = jnp.asarray(series, jnp.float32)
    if normalize:
        series = znorm(series)
    if labels is None:
        labels = jnp.full((series.shape[0],), -1, jnp.int32)
    u, lo = envelope_op(series, w)
    kim, kim_ok = kim_features(series)
    return DTWIndex(
        series=series,
        labels=jnp.asarray(labels, jnp.int32),
        upper=u,
        lower=lo,
        kim=kim,
        kim_ok=kim_ok,
        w=w,
    )
