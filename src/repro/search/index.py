"""Candidate-store index for lower-bounded NN-DTW search.

The index precomputes everything that depends only on the store and the
window ``w`` (paper SS II-B: envelopes are query-independent, so an index
amortises them across every query): the Sakoe-Chiba envelopes, the O(1)
Kim feature vector, and the int8 PAA *sketch* of every candidate.

Sketch store layout (tier -1, ``search/pipeline.py``):

  Every tier before this one reads the full ``(N, L)`` float32 store, so
  store *size* — not compute — is the scaling wall for HBM-scale corpora.
  The sketch is a segment-reduced, quantised view of the candidate's
  w-envelope: split the length axis into ``S`` segments (power of two,
  default 16; boundaries ``b[j] = j*L//S``, so ragged lengths are fine),
  take the per-segment mean of the upper/lower envelope, and quantise
  with *outward* rounding — ``ceil`` for the upper cells, ``floor`` for
  the lower — onto a shared symmetric int8 grid:

    sk_hi[n, j] = ceil(mean(upper[n, b[j]:b[j+1]]) / scale)   int8
    sk_lo[n, j] = floor(mean(lower[n, b[j]:b[j+1]]) / scale)  int8
    sk_scale    = max|segment cell| / 127 * (1 + 1e-6)        f32 scalar

  (the 1e-6 headroom keeps ``|cell/scale|`` strictly below 127, so the
  clip after ceil/floor can never round *inward* — quantisation only ever
  widens the envelope, which is what keeps the dequantised bound
  admissible; ``testing/faults.py::inward_quantiser`` proves the guard
  trips when this is violated).  The bound itself is the segment-reduced
  LB_Keogh (Cauchy-Schwarz over each segment):

    LB_sketch(q, n) = sum_j n_j * max(qbar_j - sk_hi[n,j]*scale,
                                      sk_lo[n,j]*scale - qbar_j, 0)^2
                    <= LB_Keogh(q, n) <= DTW_w(q, n)

  at 2*S = 32 bytes/candidate — a 10M-candidate sketch store is ~320 MB
  and stays on-chip where the raw series cannot.

Store-level candidate mask (``build_index(..., calibrate=cfg, mask=True)``):

  ``live[n]`` marks candidates some leave-one-out calibration query keeps
  below its measured seed threshold: after plan calibration, each sampled
  query's k seed distances give ``tau_i`` (an upper bound on its true
  k-th NN distance), and ``live[n] = any_i(LB_sketch(i, n) <= tau_i *
  mask_safety)``.  A committed plan threads ``live`` into the existing
  cross-block / pairwise liveness inputs (the kernels already take it),
  so dense-tier work on dead candidates becomes skipped tiles.  Exactness
  does not depend on the mask being right: masked tiers emit ``-inf``
  for dead candidates, whose *unmasked* cheap-tier bounds (sketch, Kim)
  stay in the running max — a dead candidate is still pruned by a valid
  bound or verified, never silently excluded.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.distances import znorm
from repro.kernels.ops import envelope_op

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DTWIndex:
    """Immutable candidate store + per-candidate precomputation.

    Attributes:
      series:  (N, L) candidate series (z-normalised if built with znorm).
      labels:  (N,) int labels (or -1s when unlabelled).
      upper:   (N, L) upper envelopes for window ``w``.
      lower:   (N, L) lower envelopes.
      kim:     (N, 4) [first, last, max, min] Kim features.
      kim_ok:  (N, 2) feature-admissibility flags [max interior, min interior].
      w:       static window the envelopes were built for.
      sk_lo:   (N, S) int8 outward-quantised lower envelope segment means
               (or None when built without a sketch).
      sk_hi:   (N, S) int8 upper counterpart.
      sk_scale: () f32 shared symmetric dequantisation scale.
      live:    (N,) bool store-level candidate mask (None = all live);
               see the module docstring for derivation and exactness.
    """

    series: Array
    labels: Array
    upper: Array
    lower: Array
    kim: Array
    kim_ok: Array
    w: int = dataclasses.field(metadata=dict(static=True))
    sk_lo: Array | None = None
    sk_hi: Array | None = None
    sk_scale: Array | None = None
    live: Array | None = None

    @property
    def n(self) -> int:
        return self.series.shape[0]

    @property
    def length(self) -> int:
        return self.series.shape[1]


def kim_features(x: Array) -> tuple[Array, Array]:
    """Per-series Kim features + interior-witness flags (see lb_kim)."""
    L = x.shape[-1]
    first = x[..., 0]
    last = x[..., -1]
    mx = jnp.max(x, -1)
    mn = jnp.min(x, -1)
    imax = jnp.argmax(x, -1)
    imin = jnp.argmin(x, -1)
    feats = jnp.stack([first, last, mx, mn], axis=-1)
    ok = jnp.stack(
        [(imax != 0) & (imax != L - 1), (imin != 0) & (imin != L - 1)],
        axis=-1,
    )
    return feats, ok


def sketch_segments(L: int, s: int) -> tuple[tuple[int, int], ...]:
    """Static segment boundaries ``b[j] = j*L//s`` as (start, stop) pairs.

    ``s`` is halved (power-of-two discipline) while it exceeds ``L`` so a
    short store never produces empty segments; ragged lengths (``L`` not
    divisible by ``s``) give segments differing by one step.
    """
    s = max(1, int(s))
    while s > L:
        s //= 2
    bounds = [j * L // s for j in range(s + 1)]
    return tuple((bounds[j], bounds[j + 1]) for j in range(s))


def sketch_segment_sizes(L: int, s: int) -> Array:
    """``(S,)`` f32 segment lengths ``n_j`` (the bound's per-segment
    Cauchy-Schwarz weights)."""
    return jnp.asarray(
        [b - a for a, b in sketch_segments(L, s)], jnp.float32
    )


def sketch_query_means(q: Array, s: int) -> Array:
    """Per-segment f32 means of a query batch: ``(..., L) -> (..., S)``.

    Query-side featurisation stays float (queries arrive at search time;
    only the *store* side is quantised, and only outward)."""
    segs = sketch_segments(q.shape[-1], s)
    return jnp.stack(
        [jnp.mean(q[..., a:b], axis=-1) for a, b in segs], axis=-1
    )


def sketch_features(
    upper: Array, lower: Array, s: int = 16
) -> tuple[Array, Array, Array]:
    """Quantise ``(N, L)`` w-envelopes into the int8 sketch store.

    Returns ``(sk_lo, sk_hi, sk_scale)`` — see the module docstring for
    the layout and the admissibility argument.  Outward rounding is the
    load-bearing invariant: ``sk_hi * scale >= mean(upper)`` and
    ``sk_lo * scale <= mean(lower)`` cell-wise, always.
    """
    segs = sketch_segments(upper.shape[-1], s)
    useg = jnp.stack(
        [jnp.mean(upper[..., a:b], axis=-1) for a, b in segs], axis=-1
    )
    lseg = jnp.stack(
        [jnp.mean(lower[..., a:b], axis=-1) for a, b in segs], axis=-1
    )
    maxabs = jnp.maximum(jnp.max(jnp.abs(useg)), jnp.max(jnp.abs(lseg)))
    # 1e-6 headroom: |cell/scale| < 127 strictly, so the clip below can
    # never pull a ceil'd/floor'd cell back inward
    scale = jnp.where(maxabs > 0.0, maxabs, 1.0) * ((1.0 + 1e-6) / 127.0)
    sk_hi = jnp.clip(jnp.ceil(useg / scale), -127, 127).astype(jnp.int8)
    sk_lo = jnp.clip(jnp.floor(lseg / scale), -127, 127).astype(jnp.int8)
    from repro.search import guards as _guards

    hook = _guards.fault_hook("sketch_feats")
    if hook is not None:
        sk_lo, sk_hi = hook(sk_lo, sk_hi)
    return sk_lo, sk_hi, scale.astype(jnp.float32)


def build_index(
    series: Array,
    w: int,
    labels: Array | None = None,
    *,
    normalize: bool = False,
    sanitize: bool = False,
    preflight: bool = False,
    calibrate: Any | None = None,
    calibrate_sample: int = 8,
    sketch: int | None = 16,
    mask: bool = False,
    mask_safety: float = 2.0,
) -> DTWIndex:
    """Build a ``DTWIndex`` for window ``w``.

    Input hygiene (concrete inputs; skipped under tracing like the
    calibration below): a store containing NaN/Inf raises — one poisoned
    value flows silently into envelopes, Kim features, and every bound
    otherwise — as does, with ``normalize=True``, a zero-variance series
    (z-norm maps it to all-zeros, which then matches every flat query at
    distance ~0).  ``sanitize=True`` masks bad values to the per-series
    finite mean, keeps flat series (znorm's epsilon maps them to zeros),
    and reports everything via a ``GuardWarning``
    (guards.validate_series).

    ``preflight`` runs ``guards.preflight_engine()`` — the single-device
    jitted-engine-vs-brute-force canary — before the store is returned,
    warning (once per process) if the compiled path is not exact on this
    jax install.  The distributed analogue lives in
    ``make_distributed_search`` (its preflight is on by default because
    the jax 0.4.x ``jit(shard_map(while))`` miscompile is a known,
    detectable failure).

    ``calibrate`` (an ``EngineConfig`` or ``CascadeConfig``) runs store-
    level plan calibration at build time: a ``calibrate_sample``-series
    sample of the store itself is searched leave-one-out through the
    instrumented tier pipeline and the planner's optimised plan is
    committed for this store/config (search/planner.py), so repeated-
    query serving starts warm — the first real query batch runs the
    committed plan instead of paying a calibration block.  The LOO
    exclusion keeps the measured threshold honest (a self-match would
    collapse ``tau`` to zero, the same argument as
    ``choose_survivor_budget``), and a LOO-calibrated plan is
    conservative for plain queries, so the committed decision serves
    both.  Calibration requires concrete (host) inputs; it is skipped
    for unstaged cascades.

    ``sketch`` sets the segment count ``S`` of the int8 PAA sketch store
    (module docstring; ``None`` skips featurisation — the sketch tier
    then scores a trivial all-zero bound and the planner drops it as
    idle).  ``mask=True`` (requires ``calibrate`` and a sketch) derives
    the store-level ``live`` mask from LOO sketch mass *before* the plan
    is calibrated, so the committed plan prices the masked tiers;
    ``mask_safety`` scales the per-query seed threshold (squared-distance
    units) the mask admits candidates under — larger is more
    conservative (more candidates stay live).
    """
    series = jnp.asarray(series, jnp.float32)
    if not isinstance(series, jax.core.Tracer):
        from repro.search import guards as _guards

        series, _ = _guards.validate_series(
            series, name="series", sanitize=sanitize, check_flat=normalize,
        )
        if preflight:
            _guards.preflight_engine()
    if normalize:
        series = znorm(series)
    if labels is None:
        labels = jnp.full((series.shape[0],), -1, jnp.int32)
    u, lo = envelope_op(series, w)
    kim, kim_ok = kim_features(series)
    sk_lo = sk_hi = sk_scale = None
    if sketch is not None:
        sk_lo, sk_hi, sk_scale = sketch_features(u, lo, sketch)
    index = DTWIndex(
        series=series,
        labels=jnp.asarray(labels, jnp.int32),
        upper=u,
        lower=lo,
        kim=kim,
        kim_ok=kim_ok,
        w=w,
        sk_lo=sk_lo,
        sk_hi=sk_hi,
        sk_scale=sk_scale,
    )
    if calibrate is not None:
        from repro.search.planner import calibrate_plan, calibration_sample

        cascade = getattr(calibrate, "cascade", calibrate)
        k = int(getattr(calibrate, "k", 1))
        if cascade.staged and not isinstance(series, jax.core.Tracer):
            # strided store sample: class-ordered stores get every class
            # into the measurement (planner.calibration_sample)
            pick = calibration_sample(index.n, calibrate_sample)
            if mask and index.sk_lo is not None:
                index = _derive_live_mask(
                    index, cascade, k, pick, mask_safety
                )
            calibrate_plan(
                index.series[pick], index, cascade, k,
                exclude=jnp.asarray(pick, jnp.int32), sample=len(pick),
                pcfg=getattr(calibrate, "planner", None),
            )
    return index


def _derive_live_mask(index, cascade, k, pick, mask_safety):
    """LOO store-level mask: candidates no calibration query keeps.

    Runs the cascade once on the calibration sample (leave-one-out
    exclusion, like the plan calibration that follows) for the measured
    seed thresholds ``tau_i`` — each an *upper* bound on query ``i``'s
    true k-th NN distance, so thresholding the admissible sketch bound
    under ``tau_i * mask_safety`` only over-admits, never over-kills, on
    the calibration distribution.  Derived before ``calibrate_plan`` so
    the committed plan prices the masked tiers.
    """
    import dataclasses as _dc

    from repro.kernels.ref import sketch_bound_ref
    from repro.search.cascade import run_plan

    qs = index.series[pick]
    cres = run_plan(
        qs, index, cascade, k=k, exclude=jnp.asarray(pick, jnp.int32)
    )
    tau = jnp.max(
        jnp.where(jnp.isfinite(cres.seed_d), cres.seed_d, 0.0), axis=1
    )
    qbar = sketch_query_means(qs, index.sk_lo.shape[1])
    seg = sketch_segment_sizes(index.length, index.sk_lo.shape[1])
    sb = sketch_bound_ref(qbar, index.sk_lo, index.sk_hi,
                          index.sk_scale, seg)
    live = jnp.any(sb <= tau[:, None] * mask_safety + 1e-6, axis=0)
    return _dc.replace(index, live=live)
