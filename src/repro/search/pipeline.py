"""Tier pipeline: the declarative vocabulary of the lower-bound cascade.

The cascade used to be a hard-coded kim -> bands -> gather -> pairwise
chain baked into ``search/cascade.py``; adding a tier (a two-pass LB, a
second bands pass at a different ``V``) or reordering the existing ones
meant rewriting the cascade.  This module factors the chain into three
explicit, composable pieces (Lemire's two-pass argument, arXiv:0811.3301:
bound tiers should *compose*, the pipeline should not care which bounds it
is running):

  * ``BoundTier`` — one bound stage: a name, a *cost class* (documentation
    + bench label: "O(1)", "O(S)", "O(V^2)", "O(L)"), a *scope*, and the
    bound function itself.  ``all_pairs`` tiers produce a dense ``(Q, N)``
    matrix over every (query, candidate); ``pairwise`` tiers refine only
    the compacted survivor pack — packed ``(P, L)`` rows -> ``(P,)``
    bounds, the layout shared by the pairwise LB kernel, the engine's flat
    verification scheduler, and the DTW kernel's pair tiles.

Tier -1 (the ``sketch`` tier) extends the scope taxonomy in one direction
without changing it: it is an ``all_pairs`` tier like Kim, but it reads
the index's quantised *feature* store (``index.sk_lo/sk_hi``, int8 PAA
segment means — search/index.py), never the ``(N, L)`` series.  That is
the design point: every tier whose operand is the raw store is bounded by
store bandwidth at HBM scale, while a feature tier's operand is ~S bytes
per candidate and stays resident.  Sketch-scope rules: the tier must
score *every* candidate (the store-level ``live`` mask is derived FROM
its bounds, so it must not consume the mask), it prices as ``"O(S)"``,
and on an index built without features it returns the all-zero bound —
trivially admissible, measured idle, dropped by the planner — so plans
mentioning it compose with any index.
  * ``Compaction`` — the single pipeline stage between the all-pairs and
    pairwise tiers: gather the ``B`` best-bounded candidates per query
    (ascending running bound) into packed batches.  Its *policy* decides
    how much of the packed width each query may refine: the default refines
    everything; a ``limit_fn`` callback computes per-query refine limits at
    trace time, which is how the distributed path allocates one *global*
    budget across shards (limits beyond the allocation keep their tier-0/1
    bound — still valid, so exactness never depends on the policy).
  * ``VerificationPlan`` — the ordered tier list + compaction + the
    verification *schedule*.  ``schedule="bound"`` argsorts every
    verification round's flat (query, candidate) batch ascending by its
    tightest bound before packing it into DTW pair tiles, so doomed pairs
    cluster into the same tiles and the kernel's per-tile liveness exit
    fires per cluster instead of almost never; ``schedule="index"`` keeps
    the unsorted stripe order (the PR 2 baseline the bench measures
    against).  The schedule is a packing permutation only — results and
    per-query ``n_dtw`` are invariant under it.

Every stage of this pipeline is also a *checked invariant boundary*
(search/guards.py): tier outputs pass a finite-value gate (a registered
tier that emits NaN degrades its pairs to verification instead of
poisoning the ranking), the compaction gather is covered by the
survivor-mass conservation check (every selected candidate appears in
the pack exactly once, scatter-max refinement is monotone), and the
executor's seed verification doubles as the admissibility spot-check
(tier bound <= verified DTW).  A custom tier therefore does not need to
be trusted to be *correct* to be safe to register — an inadmissible
bound trips the guard and the engine serves the reference fallback —
but it does need to be admissible to be *useful*.  The deterministic
fault injectors in testing/faults.py target exactly these stage
boundaries (``tier_out``, ``compaction_cand``, ``packed_rows``).

Registering a custom tier (worked example — this exact pattern is
exercised by tests/test_scheduler.py and tests/test_planner.py).  A
registered tier is not just runnable, it is *priced*: the executor can
measure its realised pruning mass against its cost class (``TierStats``
below), and the planner (search/planner.py) drops it from the committed
plan when the measurement says it does not pay — no hand-tuning:

    from repro.search import pipeline as pl

    @pl.register_tier("bands_v2")
    def bands_v2_tier() -> pl.BoundTier:
        # a second, cheaper bands pass at V=2 in front of the V=4 one
        def fn(q, index, cfg):
            from repro.search.cascade import bands_prefilter
            import dataclasses
            return bands_prefilter(q, index, dataclasses.replace(cfg, v=2))
        return pl.BoundTier("bands_v2", cost="O(V^2)", scope="all_pairs",
                            fn=fn)

    plan = pl.default_plan(cfg)
    plan = dataclasses.replace(
        plan, tiers=(pl.get_tier("kim"), pl.get_tier("bands_v2"),
                     *plan.tiers[1:]))
    ecfg = EngineConfig(cascade=cfg, k=1, auto_plan=True)
    res, stats = nn_search(index, queries, ecfg, plan=plan,
                           with_stats=True)      # exactness is untouched
    print(stats.table())
    # tier        cost    scored   mass  mass%   work  mass/work
    # kim         O(1)      3072    410  13.3%   3.1e3  1.3e-1
    # bands_v2    O(V^2)    3072      0   0.0%   1.2e4  0.0      <- dropped
    # bands       O(V^2)    3072   2231  72.6%   4.9e4  4.5e-2
    # ...
    # committed: kim -> bands -> enhanced_pairwise   dropped: bands_v2

The V=2 pass here is fully shadowed by the V=4 pass that runs after it,
so its measured incremental mass is zero and the committed plan stops
paying for it from the second query block on.  ``list_tiers()`` /
``unregister_tier()`` keep calibration experiments from leaking registry
state across tests.

Every tier must return a valid lower bound on ``DTW_w``; the executor
(cascade.run_plan) keeps the running elementwise max, so a loose custom
tier can only cost work, never correctness — and the planner can only
*remove* tier work, so a committed plan inherits exactness from the same
argument.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Callable

import jax

Array = jax.Array

# ---------------------------------------------------------------------------
# pipeline vocabulary
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BoundTier:
    """One composable bound stage of the cascade.

    Attributes:
      name: stable identifier (registry key, bench label; the planner
        keys its drop/reorder decisions by name, so tiers sharing a plan
        must have distinct names).
      cost: cost class per pair ("O(1)", "O(V)", "O(V^2)", "O(L)",
        "O(L*W)").  Since the planner, this is *priced*, not just
        documentation: ``tier_cost_weight`` turns it into the work
        denominator of the mass/cost ratio the plan optimiser gates on,
        and unrecognised spellings price at ``O(L)`` — declare a known
        class or expect dense-tier pricing.
      scope: ``"all_pairs"`` (fn maps ``(q, index, cfg) -> (Q, N)`` bounds)
        or ``"pairwise"`` (fn maps packed rows
        ``(qrows, crows, urows, lrows, cfg) -> (P,)`` bounds over the
        compacted survivors; when the plan's compaction carries a
        ``limit_fn`` the executor also passes ``live=`` — a ``(P,)``
        slot-liveness mask the tier should honour by returning ``-inf``
        on dead slots, ideally skipping their work like the built-in
        kernel does).
      fn: the bound function for that scope.  Must return a valid lower
        bound on ``DTW_w`` for every pair it scores.
    """

    name: str
    cost: str
    scope: str
    fn: Callable

    def __post_init__(self):
        if self.scope not in ("all_pairs", "pairwise"):
            raise ValueError(f"unknown tier scope: {self.scope!r}")


@dataclasses.dataclass(frozen=True)
class Compaction:
    """Gather-compaction policy between all-pairs and pairwise tiers.

    Attributes:
      budget: static per-query packed width ``B`` override; ``None`` defers
        to ``CascadeConfig.budget`` (static bucket rule / adaptive memo).
      limit_fn: optional traceable callback ``(lb01, budget, k) -> (Q,)``
        int limits: query ``i`` refines only its first ``limit[i]`` packed
        slots (ascending tier-0/1 bound — the tightest survive), the rest
        keep their tier-0/1 bound.  This is the *global survivor budget*
        hook: the distributed path all-gathers per-shard tier-0/1 minima
        inside ``limit_fn`` and returns this shard's mass-proportional
        share.  ``None`` refines the full packed width.
      width_scale: with a ``limit_fn`` the *static* packed width is
        ``min(n, width_scale * B)`` so a skewed shard can be allocated more
        than the uniform per-shard budget while shapes stay trace-static.
        The executor turns the per-query limits into a per-slot ``live``
        mask for the pairwise tiers; the built-in kernel skips fully-dead
        pair tiles outright (kernels/lb_enhanced_pairwise.py), so the
        static width costs a light shard VMEM shape, not FLOPs — the
        allocation moves real work between shards, not just tightness
        (see search/distributed.py).
    """

    budget: int | None = None
    limit_fn: Callable | None = None
    width_scale: int = 2


@dataclasses.dataclass(frozen=True)
class VerificationPlan:
    """Ordered tiers + compaction + verification schedule.

    The executor (cascade.run_plan) runs the ``all_pairs`` tiers in order
    (running elementwise max), compacts once, then runs the ``pairwise``
    tiers on the packed survivors.  ``all_pairs`` tiers listed after a
    ``pairwise`` tier are rejected — the pipeline has exactly one
    compaction point.

    ``schedule`` steers the engine's verification loop:
      * ``"bound"``: each round's flat batch is argsorted ascending by its
        tightest bound and the permutation is pushed into the DTW kernel's
        pair-tile packing (kernels/ops.py ``perm=``) — doomed pairs land in
        the same tiles, converting the per-tile liveness exit into an
        effective per-pair early exit;
      * ``"index"``: PR 2's unsorted stripe packing (bench baseline).

    ``verify_tile_p`` makes the pair-tile size a scheduler decision: it is
    threaded into every verification DTW dispatch (the engine's rounds and
    ``run_plan``'s seed verification) as the kernel's ``tile_p`` cap.
    ``None`` defers to the per-round policy — bound-ordered engine rounds
    shrink the tile (``kernels.tiling.sched_pair_tile``) so the doomed
    cluster's boundary lands on a tile boundary and the liveness exit
    fires there, while seed verification and unsorted rounds keep the
    kernel default (seeds are the tightest-bound pairs: almost all live,
    nothing to exit, so full tiles win).  Tile size is packing geometry
    only — results and per-query ``n_dtw`` are invariant under it
    (property-tested like the schedule itself).
    """

    tiers: tuple[BoundTier, ...]
    compaction: Compaction = Compaction()
    schedule: str = "bound"
    verify_tile_p: int | None = None

    def __post_init__(self):
        if self.schedule not in ("bound", "index"):
            raise ValueError(f"unknown schedule: {self.schedule!r}")
        seen_pairwise = False
        for t in self.tiers:
            if t.scope == "pairwise":
                seen_pairwise = True
            elif seen_pairwise:
                raise ValueError(
                    "all_pairs tier after a pairwise tier: the pipeline "
                    f"has one compaction point (tier {t.name!r})"
                )

    @property
    def all_pairs_tiers(self) -> tuple[BoundTier, ...]:
        return tuple(t for t in self.tiers if t.scope == "all_pairs")

    @property
    def pairwise_tiers(self) -> tuple[BoundTier, ...]:
        return tuple(t for t in self.tiers if t.scope == "pairwise")


# ---------------------------------------------------------------------------
# tier pricing: measured mass / cost-weighted work
# ---------------------------------------------------------------------------


def bucket_pow2(x: int, floor: int) -> int:
    """Round ``x`` up to the next power-of-two bucket (>= ``floor``) —
    the one bucketing rule behind both the cascade's survivor budgets
    (floor 64, see cascade.py) and the planner's committed right-sizing
    (floor 8): bounded bucket vocabulary = bounded recompilation."""
    b = floor
    while b < x:
        b <<= 1
    return b


def tier_cost_weight(cost: str, L: int, v: int, w: int,
                     s: int = 16) -> float:
    """Per-pair work weight of a tier's declared cost class.

    The cost class strings were documentation until now; the planner
    prices tiers with them, so the executor turns them into per-pair
    weights here (one definition for stats, planner, and bench).
    ``"O(S)"`` is the sketch tier's class (``s`` = segment count of the
    int8 feature store, default 16).  Unrecognised classes price at
    ``O(L)`` — the costliest *built-in* class — which under-charges
    anything genuinely ``O(L*W)``-shaped, so a custom tier above
    ``O(L)`` should declare one of the recognised spellings to be priced
    (and gated) honestly.
    """
    key = cost.replace(" ", "").upper()
    if key == "O(1)":
        return 1.0
    if key == "O(S)":
        return float(max(s, 1))
    if key == "O(V)":
        return float(max(v, 1))
    if key in ("O(V^2)", "O(V2)", "O(V*V)"):
        return float(max(v, 1)) ** 2
    if key == "O(L)":
        return float(max(L, 1))
    if key in ("O(L*W)", "O(LW)", "O(W*L)", "O(WL)"):
        return float(max(L, 1)) * float(max(min(w, L), 1))
    return float(max(L, 1))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TierStats:
    """Measured per-tier pruning mass + cost-weighted work for one plan.

    This generalises what ``choose_survivor_budget`` estimates (survivor
    mass under a verified threshold) into a reusable per-tier accumulator:
    ``cascade.run_plan(collect_stats=True)`` fills one of these while
    executing a plan, pricing every tier against the seed-verified
    threshold ``tau`` (the k-th seed distance upper-bounds the final k-th
    best, so a pair whose running bound reaches ``tau`` is realised
    pruning — the paper's pruning-power numerator, attributed to the tier
    that crossed it).  All measured fields are arrays, so the struct is a
    pytree: it traces through ``jit``/``shard_map`` and the distributed
    path can ``psum`` it across shards before anyone syncs to host
    (search/distributed.py ``gather_tier_stats``).

    Attributes:
      names/costs/scopes: static per-tier labels, in plan order.
      mass: (T,) incremental realised pruning mass — pairs whose running
        bound first reached ``tau`` at this tier.
      scored: (T,) pairs the tier actually scored (pairwise tiers under a
        refine limit score only their live slots).
      work: (T,) ``scored * tier_cost_weight(cost)`` — the cost-weighted
        denominator of the planner's mass/cost ratio.
      pairs: () total measured (query, candidate) pairs (excluded
        candidates removed).
      queries: () measured query count.
      survivors: (Q,) per-query cheap-tier survivor mass at ``tau`` —
        ``choose_survivor_budget``'s estimator, kept per query so the
        planner can bucket a refine limit from it.
    """

    names: tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    costs: tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    scopes: tuple[str, ...] = dataclasses.field(metadata=dict(static=True))
    mass: Array
    scored: Array
    work: Array
    pairs: Array
    queries: Array
    survivors: Array

    def mass_per_work(self):
        """(T,) realised mass per unit of cost-weighted work (host-side)."""
        import numpy as np

        w = np.maximum(np.asarray(self.work, dtype=float), 1e-30)
        return np.asarray(self.mass, dtype=float) / w

    def table(self) -> str:
        """Human-readable per-tier pricing table (host-side)."""
        import numpy as np

        pairs = max(float(self.pairs), 1.0)
        ratio = self.mass_per_work()
        rows = [f"{'tier':<20} {'cost':<8} {'scored':>9} {'mass':>9} "
                f"{'mass%':>7} {'work':>10} {'mass/work':>10}"]
        for i, name in enumerate(self.names):
            m = float(np.asarray(self.mass)[i])
            s = float(np.asarray(self.scored)[i])
            wk = float(np.asarray(self.work)[i])
            rows.append(
                f"{name:<20} {self.costs[i]:<8} {s:>9.0f} {m:>9.0f} "
                f"{100.0 * m / pairs:>6.1f}% {wk:>10.3g} {ratio[i]:>10.3g}"
            )
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# tier registry + the built-in tiers
# ---------------------------------------------------------------------------

_TIER_REGISTRY: dict[str, Callable[[], BoundTier]] = {}


def register_tier(name: str):
    """Decorator: register a zero-arg ``BoundTier`` factory under ``name``."""

    def deco(factory: Callable[[], BoundTier]):
        _TIER_REGISTRY[name] = factory
        return factory

    return deco


def get_tier(name: str) -> BoundTier:
    try:
        return _TIER_REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown tier {name!r}; registered: {sorted(_TIER_REGISTRY)}"
        ) from None


def list_tiers() -> tuple[str, ...]:
    """Sorted names of every registered tier factory.

    The listing half of the registry's bookkeeping pair (with
    ``unregister_tier``): calibration experiments that register throwaway
    tiers can enumerate and remove exactly what they added instead of
    leaking registry state across tests.
    """
    return tuple(sorted(_TIER_REGISTRY))


def registered_tiers() -> tuple[str, ...]:
    """Alias of ``list_tiers`` (the pre-planner name, kept for callers)."""
    return list_tiers()


def unregister_tier(name: str) -> bool:
    """Remove a registered tier factory; ``True`` if it was present.

    Idempotent: unregistering a name twice (or a name never registered)
    is a no-op returning ``False``, so test teardown never races.
    """
    return _TIER_REGISTRY.pop(name, None) is not None


@register_tier("sketch")
def _sketch_tier() -> BoundTier:
    """Tier -1: O(S)/pair quantised sketch bound from the int8 PAA
    feature store (module docstring; search/index.py for the layout).

    Scores every candidate unconditionally — the store-level ``live``
    mask is *derived from* these bounds, so this tier must never consume
    it.  On an index built without features the bound is all zeros
    (valid, idle, planner-dropped), which is what lets ``default_plan``
    include the tier without knowing the index.
    """

    def fn(q, index, cfg):
        import jax.numpy as jnp

        if getattr(index, "sk_lo", None) is None:
            return jnp.zeros((q.shape[0], index.n), jnp.float32)
        from repro.kernels import ref as _ref
        from repro.kernels.ops import sketch_bound_op
        from repro.search.index import (
            sketch_query_means,
            sketch_segment_sizes,
        )

        s = index.sk_lo.shape[1]
        qbar = sketch_query_means(q, s)
        seg = sketch_segment_sizes(index.length, s)
        op = sketch_bound_op if cfg.use_pallas else _ref.sketch_bound_ref
        return op(qbar, index.sk_lo, index.sk_hi, index.sk_scale, seg)

    return BoundTier("sketch", cost="O(S)", scope="all_pairs", fn=fn)


@register_tier("lb_improved")
def _lb_improved_tier() -> BoundTier:
    """Lemire's two-pass LB_Improved (arXiv:0811.3301) over the packed
    survivor rows — optional, jnp-only, priced like any tier.

    Pass 1 is LB_Keogh of the query against the candidate's (index-
    precomputed) envelope; pass 2 projects the query onto that envelope
    and runs LB_Keogh of the *candidate* against the projection's
    envelope (core/envelopes.py — batched, so the packed ``(P, L)``
    layout runs in one shot).  Sum of the two passes is Lemire's bound.
    Registered but not in ``default_plan``: the point it pins is that a
    second real bound is a config edit plus this factory — the planner
    prices it per store and keeps it only where the measured mass says
    the extra O(L) pass pays.
    """

    def fn(qrows, crows, urows, lrows, cfg, *, live=None):
        import jax.numpy as jnp

        from repro.core.envelopes import envelope
        from repro.core.lower_bounds import lb_keogh_env

        first = lb_keogh_env(qrows, urows, lrows)
        proj = jnp.clip(qrows, lrows, urows)
        up, lp = envelope(proj, cfg.w)
        out = first + lb_keogh_env(crows, up, lp)
        if live is not None:
            liv = jnp.broadcast_to(
                jnp.asarray(live), out.shape
            ).astype(bool)
            out = jnp.where(liv, out, float("-inf"))
        return out

    return BoundTier("lb_improved", cost="O(L)", scope="pairwise", fn=fn)


@register_tier("kim")
def _kim_tier() -> BoundTier:
    """O(1)/pair Kim bound from precomputed index features."""

    def fn(q, index, cfg):
        from repro.search.cascade import lb_kim_tier

        return lb_kim_tier(q, index)

    return BoundTier("kim", cost="O(1)", scope="all_pairs", fn=fn)


@register_tier("bands")
def _bands_tier() -> BoundTier:
    """O(V^2)/pair elastic-bands tier (Alg. 1 lines 1-11).

    Honours the store-level ``live`` mask (cross-block kernel liveness:
    dead candidates emit ``-inf``, fully-dead candidate tiles skip)."""

    def fn(q, index, cfg, *, live=None):
        from repro.search.cascade import bands_prefilter

        return bands_prefilter(q, index, cfg, live=live)

    return BoundTier("bands", cost="O(V^2)", scope="all_pairs", fn=fn)


@register_tier("enhanced_pairwise")
def _enhanced_pairwise_tier() -> BoundTier:
    """O(L)/pair fused LB_ENHANCED^V over the packed survivor rows."""

    def fn(qrows, crows, urows, lrows, cfg, *, live=None):
        return cfg.pairwise_fn()(qrows, crows, urows, lrows, cfg.w, cfg.v,
                                 live=live)

    return BoundTier("enhanced_pairwise", cost="O(L)", scope="pairwise",
                     fn=fn)


@register_tier("enhanced_dense")
def _enhanced_dense_tier() -> BoundTier:
    """O(L)/pair LB_ENHANCED^V on *all* pairs — the unstaged diagnostic
    tier (cross-block kernel shape), bypassing compaction entirely."""

    def fn(q, index, cfg, *, live=None):
        from repro.search.cascade import enhanced_all_pairs

        return enhanced_all_pairs(q, index, cfg, live=live)

    return BoundTier("enhanced_dense", cost="O(L)", scope="all_pairs", fn=fn)


def default_plan(cfg, *, schedule: str = "bound") -> VerificationPlan:
    """The paper's staged cascade as a tier list: [sketch ->] kim ->
    bands -> compact -> pairwise LB_ENHANCED.  ``cfg.use_sketch=True``
    prepends the tier-(-1) sketch (safe with any index — see the sketch
    tier factory); ``cfg.use_kim=False`` drops the Kim tier."""
    tiers = []
    if getattr(cfg, "use_sketch", False):
        tiers.append(get_tier("sketch"))
    if cfg.use_kim:
        tiers.append(get_tier("kim"))
    tiers.append(get_tier("bands"))
    tiers.append(get_tier("enhanced_pairwise"))
    return VerificationPlan(tiers=tuple(tiers), schedule=schedule)


def dense_plan(cfg, *, schedule: str = "bound") -> VerificationPlan:
    """The seed behaviour: every pair pays the full O(L) tier (diagnostics
    and the baseline the staged pipeline is property-tested against)."""
    tiers = []
    if getattr(cfg, "use_sketch", False):
        tiers.append(get_tier("sketch"))
    if cfg.use_kim:
        tiers.append(get_tier("kim"))
    tiers.append(get_tier("enhanced_dense"))
    return VerificationPlan(tiers=tuple(tiers), schedule=schedule)


# ---------------------------------------------------------------------------
# adaptive survivor-budget memo
# ---------------------------------------------------------------------------

# choose_survivor_budget costs one tier-0/1 pass plus S*k uncut DTWs, so the
# chosen bucket is cached and re-estimated only when the store or the
# query shape of the problem changes.  The key is explicit about what the
# estimate depends on — the *index* (series identity + size), the window
# ``w``, and ``k`` — plus the config knobs that change which bounds the
# estimator runs.  A budget chosen for k=1 must never be reused for a
# larger k: tau is the k-th seed distance, so the survivor mass grows with
# k and a stale k=1 bucket would silently under-cover the refinement.
# Entries hold a weakref to the series array and hit only while that exact
# array is alive — a freed buffer whose id() gets reused cannot inherit a
# stale budget.
_BUDGET_CACHE: dict = {}
_BUDGET_CACHE_MAX = 64


def _budget_cache_key(index, cascade, k: int, exclude) -> tuple:
    return (
        id(index.series),            # index identity (validated by weakref)
        index.n,                     # index size
        cascade.w,                   # window the bounds are built for
        k,                           # tau = k-th seed distance -> mass
        cascade.v,
        cascade.use_kim,
        cascade.use_pallas,
        exclude is not None,
    )


def budget_cache_clear() -> None:
    _BUDGET_CACHE.clear()


def budget_cache_len() -> int:
    return len(_BUDGET_CACHE)


def resolve_adaptive_budget(q, index, cascade, k: int, exclude) -> int:
    """Memoised ``choose_survivor_budget`` — see ``_budget_cache_key`` for
    exactly what the memo keys on.  Concrete (host) inputs only."""
    from repro.search.cascade import choose_survivor_budget

    ckey = _budget_cache_key(index, cascade, k, exclude)
    hit = _BUDGET_CACHE.get(ckey)
    if hit is not None and hit[0]() is index.series:
        return hit[1]
    budget = choose_survivor_budget(q, index, cascade, k, exclude=exclude)
    if len(_BUDGET_CACHE) >= _BUDGET_CACHE_MAX:
        _BUDGET_CACHE.clear()
    _BUDGET_CACHE[ckey] = (weakref.ref(index.series), budget)
    return budget
