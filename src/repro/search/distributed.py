"""Distributed NN-DTW search over a (pod, data, model) device mesh.

Sharding contract (DESIGN.md SS6):
  * the candidate store is sharded along its N axis over the *data* axes
    (``('data',)`` single-pod, ``('pod', 'data')`` multi-pod) — this is the
    axis that grows with corpus size, the paper's scaling bottleneck;
  * the query batch is sharded over the *model* axis — queries are
    independent, so this is embarrassing parallelism;
  * each device runs the full tier pipeline + verification engine on its
    local shard, then the per-query top-k candidates are merged with a
    single ``all_gather`` over the data axes (k * n_data_shards values per
    query — tiny compared to the local work it summarises).

Global survivor budget (``global_budget=True``): the tier pipeline's
compaction is per shard, and a purely *local* budget distributes pairwise
refinement uniformly on skewed stores — a shard holding none of a query's
plausible neighbours gets exactly as much bound tightening as the shard
holding all of them, so the shard that decides the query's fate may enter
verification with bounds far looser than the fleet could afford it.  The
global budget reuses the pipeline's compaction primitive
(cascade.run_plan + pipeline.Compaction.limit_fn) with a policy that spans
the mesh:

  1. each shard computes its all-pairs (tier-0/1) bounds locally and
     ``all_gather``s two per-query scalars over the data axes: its k-th
     smallest cheap bound, and its survivor *mass* — how many local
     candidates beat the tightest shard's k-th minimum;
  2. the uniform total budget ``D * B`` is split per query in proportion
     to shard mass (float ceil share, clamped to the static packed width
     ``2 * B``), so the shard that holds the real neighbourhood refines
     up to twice the uniform share while empty shards drop to the floor;
  3. each shard's packed pairwise batch then flows through the existing
     ``lb_enhanced_pairwise`` layout unchanged — the allocation is a
     per-query *refine limit* over the packed slots, not a new shape.

Shapes stay trace-static — every shard's packed batch is the same
``2 * B`` width — but the allocation is now *work*, not just tightness:
the executor threads each query's refine limit into the pairwise tier as
a per-slot ``live`` mask, and the kernel skips fully-dead pair tiles
outright (the same SMEM-flag liveness mechanism the DTW tiles use — see
kernels/lb_enhanced_pairwise.py), so a light shard's unallocated slots
cost neither FLOPs nor bound tightness (they keep their tier-0/1 bound —
still a valid lower bound, so exactness of the merged result never
depends on the policy; tested against single-device brute force on
skewed shards).  The remaining savings land downstream, where tighter
bounds on the heavy shard mean fewer DTW verifications and earlier
kernel abandons.

The communication volume is O(Q * shards) scalars for the budget exchange
plus O(Q * k * shards) floats for the top-k merge — independent of both N
and L — so the collective roofline term stays negligible at any corpus
size (quantified in EXPERIMENTS.md SSRoofline).

Known limitation (jax 0.4.x), now *detected* instead of documented:
wrapping the search step in an *outer* ``jax.jit`` miscompiles the
engine's data-dependent verification ``while_loop`` under
``shard_map(check_rep=False)`` — results silently drop candidates
(reproduced against brute force down to mesh (4, 2), N=32, L=16;
``check_rep=True`` is unavailable: 0.4.x has no replication rule for
``while``).  ``make_distributed_search`` therefore runs
``guards.preflight_shard_map`` by default (``jit="auto"``): the real
search step is jitted on a tiny canary store on the *same mesh* and
compared against host brute force — exact means the returned step is
``jax.jit``-wrapped (jax >= 0.6 takes this path), a mismatch means the
safe unjitted per-shard-compiled step is returned and a ``GuardWarning``
fires once per process.  The verdict is cached per (mesh shape, axes,
jax version), so the canary cost is paid once.
``tests/test_distributed.py`` pins the detection itself: the auto path
must be exact on the exact mesh/shape that miscompiles, and the raw
jitted step must disagree with brute force iff preflight said so.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.search import guards as _guards
from repro.search.engine import EngineConfig, nn_search
from repro.search.index import DTWIndex
from repro.search.pipeline import (
    Compaction,
    TierStats,
    VerificationPlan,
    default_plan,
    dense_plan,
)

Array = jax.Array


def _axis_size(axis: str) -> Array:
    if hasattr(lax, "axis_size"):                      # jax >= 0.6
        return lax.axis_size(axis)
    return lax.psum(1, axis)                           # jax 0.4.x


def _combined_axis_index(axes: Sequence[str]) -> Array:
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_size(a) + lax.axis_index(a)
    return idx


def global_budget_limit_fn(axes: tuple[str, ...]):
    """Compaction ``limit_fn`` allocating one global budget across shards.

    Returns a traceable ``(lb01, budget, k) -> (Q,)`` callback for use
    *inside* ``shard_map`` over ``axes``: all-gathers each shard's
    per-query k-th smallest tier-0/1 bound, takes the tightest shard's
    value as the survivor threshold, all-gathers the per-shard survivor
    mass under that threshold, and returns this shard's mass-proportional
    share of the global ``D * budget`` (ceil division; ``run_plan`` clamps
    it into ``[k, 2 * budget]``).  Excluded candidates arrive as +inf in
    ``lb01`` and never count toward mass.
    """

    def limit_fn(lb01: Array, budget: int, k: int) -> Array:
        n_local = lb01.shape[1]
        kq = max(1, min(k, n_local))
        neg, _ = lax.top_k(-lb01, kq)
        kth_local = -neg[:, kq - 1]                    # (Q,) local k-th min
        kth_all = lax.all_gather(kth_local, axes)      # (D, Q)
        theta = jnp.min(kth_all, axis=0)               # tightest shard's
        mass_local = jnp.sum(lb01 <= theta[:, None], axis=1)    # (Q,)
        mass_all = lax.all_gather(mass_local, axes)    # (D, Q)
        total = jnp.maximum(jnp.sum(mass_all, axis=0), 1)
        n_shards = mass_all.shape[0]
        # float share: the integer product n_shards * budget * mass would
        # wrap int32 at production scale (256 data shards x budget 1024 x
        # ~1e5 survivors) and pin the heaviest shard to the floor; the
        # fraction is exact enough and run_plan clamps the result anyway
        frac = mass_local.astype(jnp.float32) / total.astype(jnp.float32)
        want = jnp.ceil(float(n_shards * budget) * frac)
        return want.astype(jnp.int32)

    return limit_fn


def _default_distributed_plan(
    cfg: EngineConfig,
    axes: tuple[str, ...],
    global_budget: bool,
) -> VerificationPlan:
    plan = (
        default_plan(cfg.cascade) if cfg.cascade.staged
        else dense_plan(cfg.cascade)
    )
    if global_budget and cfg.cascade.staged:
        plan = dataclasses.replace(
            plan, compaction=Compaction(limit_fn=global_budget_limit_fn(axes))
        )
    return plan


def gather_tier_stats(
    stats: TierStats,
    data_axes: tuple[str, ...],
    query_axis: str | None = None,
) -> TierStats:
    """Merge shard-local ``TierStats`` into one fleet measurement.

    For use *inside* ``shard_map`` (the same collective machinery as
    ``global_budget_limit_fn``): per-tier mass/scored/work and the pair
    count are summed over every shard (candidate partitions over the data
    axes, disjoint query blocks over ``query_axis``), the query count over
    the query axis only, and the per-query survivor counts are
    max-reduced — the committed refine limit must cover the *heaviest*
    shard's measured need, not the fleet average.  After the merge every
    shard holds the same global measurement, so every shard derives the
    same plan decision — one committed plan for the fleet.
    """
    daxes = tuple(data_axes)
    axes = daxes + ((query_axis,) if query_axis is not None else ())
    surv = lax.pmax(stats.survivors, daxes)
    if query_axis is not None:
        surv = lax.pmax(jnp.max(surv, keepdims=True), query_axis)
    return dataclasses.replace(
        stats,
        mass=lax.psum(stats.mass, axes),
        scored=lax.psum(stats.scored, axes),
        work=lax.psum(stats.work, axes),
        pairs=lax.psum(stats.pairs, axes),
        queries=(
            lax.psum(stats.queries, (query_axis,))
            if query_axis is not None else stats.queries
        ),
        survivors=surv,
    )


def calibrate_distributed_plan(
    mesh: Mesh,
    cfg: EngineConfig,
    series, labels, upper, lower, kim, kim_ok, queries,
    *,
    data_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
    global_budget: bool = True,
    sample: int = 8,
    pcfg=None,
) -> "PlanDecision":
    """Measure the base plan across the mesh and derive one global plan.

    The distributed calibrate-then-commit: every shard runs the
    instrumented executor on a ``sample``-query block of its local query
    shard against its local candidate shard, the shard measurements are
    ``psum``/``pmax``-merged over the mesh (``gather_tier_stats`` — the
    ``global_budget_limit_fn`` gather machinery applied to stats), and the
    host turns the *global* measurement into a single ``PlanDecision``.
    Because the merged stats are identical on every shard, the decision is
    too: pass ``decision.plan`` to ``make_distributed_search(plan=...)``
    and all shards commit to the same rewritten plan, with the planner's
    refine limit composed into the global-budget allocation
    (``limit = min(mass-proportional share, committed cap)``).

    Takes the sharded index leaves + queries the search step itself takes.
    Calibration cost: one instrumented bound pass + ``sample * k`` seed
    DTWs per shard, paid once per (store, config).
    """
    from repro.search.cascade import run_plan
    from repro.search.planner import calibration_sample, optimise_plan

    axes = tuple(data_axes)
    base = _default_distributed_plan(cfg, axes, global_budget)
    k = cfg.k
    n_data_shards = 1
    for a in axes:
        n_data_shards *= mesh.shape[a]

    def probe(series, labels, upper, lower, kim, kim_ok, queries):
        index = DTWIndex(
            series=series, labels=labels, upper=upper, lower=lower,
            kim=kim, kim_ok=kim_ok, w=cfg.cascade.w,
        )
        # strided local sample (static indices): every region of a
        # class-ordered query shard lands in the measurement
        qs = queries[calibration_sample(queries.shape[0], sample)]
        cres = run_plan(qs, index, cfg.cascade, base, k=k,
                        collect_stats=True)
        st = gather_tier_stats(cres.stats, axes, query_axis)
        return (st.mass, st.scored, st.work, st.pairs[None],
                st.queries[None], st.survivors)

    in_specs = (
        P(axes, None), P(axes), P(axes, None), P(axes, None),
        P(axes, None), P(axes, None), P(query_axis, None),
    )
    out_specs = (P(None), P(None), P(None), P(None), P(None), P(None))
    from repro.distributed.sharding import shard_map_compat
    probe_fn = shard_map_compat(
        probe, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )
    mass, scored, work, pairs, n_q, surv = probe_fn(
        series, labels, upper, lower, kim, kim_ok, queries
    )
    stats = TierStats(
        names=tuple(t.name for t in base.tiers),
        costs=tuple(t.cost for t in base.tiers),
        scopes=tuple(t.scope for t in base.tiers),
        mass=mass, scored=scored, work=work,
        pairs=pairs[0], queries=n_q[0], survivors=surv,
    )
    n_local = max(1, series.shape[0] // n_data_shards)
    return optimise_plan(
        base, stats, n=n_local, k=k,
        base_budget=cfg.cascade.budget(n_local, k), pcfg=pcfg,
    )


def _build_step(
    mesh: Mesh,
    cfg: EngineConfig,
    *,
    data_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
    global_budget: bool = True,
    plan: VerificationPlan | None = None,
    with_guards: bool = False,
    with_sketch: bool = False,
):
    """The raw (unjitted) shard_map search step — shared by
    ``make_distributed_search`` and the preflight canary (which must
    build the *real* step: the minimal while_loop repro does not trip
    the 0.4.x miscompile, the engine's verification loop does).

    ``with_sketch`` extends the leaf contract with the quantised sketch
    store (search/index.py): ``sk_lo``/``sk_hi`` row-shard along N like
    every per-candidate feature, ``sk_scale`` (a store-wide scalar)
    replicates, and the store-level candidate mask ``live`` vec-shards
    along N — the mask is per *candidate*, so each shard masks exactly
    its own rows and the top-k merge semantics are untouched (dead
    candidates keep their finite cheap-tier bounds; see cascade.run_plan).
    ``False`` (the default) keeps the historical 7-leaf shape that the
    preflight canary, the subprocess repro scripts, and every existing
    caller pin."""
    axes = tuple(data_axes)
    if plan is None:
        plan = _default_distributed_plan(cfg, axes, global_budget)
    gcfg = _guards.resolve_guards(cfg.guards)

    def local_step(series, labels, upper, lower, kim, kim_ok, queries,
                   sk_lo=None, sk_hi=None, sk_scale=None, live=None):
        index = DTWIndex(
            series=series, labels=labels, upper=upper, lower=lower,
            kim=kim, kim_ok=kim_ok, w=cfg.cascade.w,
            sk_lo=sk_lo, sk_hi=sk_hi, sk_scale=sk_scale, live=live,
        )
        res, grep = nn_search(index, queries, cfg, plan=plan,
                              with_guards=True)
        n_local = series.shape[0]
        gidx = res.idx + (_combined_axis_index(axes) * n_local).astype(jnp.int32)
        # merge local top-k across the data axes
        d_all = lax.all_gather(res.dists, axes)        # (D, Qloc, k)
        i_all = lax.all_gather(gidx, axes)
        hook = _guards.fault_hook("allgather_topk")
        if hook is not None:
            d_all = hook(d_all)
        if gcfg.enabled and gcfg.conservation:
            # shard-dropout echo check: this shard's own top-k must come
            # back intact from the gather — a dead or corrupted shard
            # loses candidates from every query's merge, silently
            mine = jnp.take(d_all, _combined_axis_index(axes), axis=0)
            lost = jnp.sum(
                jnp.any(mine != res.dists, axis=-1)
            ).astype(jnp.float32)
            grep = dataclasses.replace(
                grep,
                conserve_checked=grep.conserve_checked
                + float(res.dists.shape[0]),
                conserve_viol=grep.conserve_viol + lost,
            )
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(res.dists.shape[0], -1)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(res.dists.shape[0], -1)
        k = res.dists.shape[1]
        neg, sel = lax.top_k(-d_flat, k)
        merged_d = -neg
        merged_i = jnp.take_along_axis(i_flat, sel, axis=1)
        n_dtw = lax.psum(res.n_dtw, axes)
        if not with_guards:
            return merged_d, merged_i, n_dtw
        # fleet-wide guard merge, TierStats-style: counts psum over the
        # whole mesh, the admissibility gap pmaxes; the flat vector form
        # crosses the out_specs as a plain replicated array
        gv = grep.to_vector()
        all_axes = axes + (query_axis,)
        merged = lax.psum(gv, all_axes)
        gap_i = _guards._VEC_FIELDS.index("admiss_gap")
        merged = merged.at[gap_i].set(lax.pmax(gv[gap_i], all_axes))
        return merged_d, merged_i, n_dtw, merged

    in_specs = (
        P(axes, None),   # series      (N, L)  sharded on N
        P(axes),         # labels      (N,)
        P(axes, None),   # upper       (N, L)
        P(axes, None),   # lower       (N, L)
        P(axes, None),   # kim         (N, 4)
        P(axes, None),   # kim_ok      (N, 2)
        P(query_axis, None),  # queries (Q, L) sharded on Q
    )
    if with_sketch:
        in_specs = in_specs + (
            P(axes, None),   # sk_lo    (N, S)  int8, sharded on N
            P(axes, None),   # sk_hi    (N, S)  int8, sharded on N
            P(),             # sk_scale ()      store-wide, replicated
            P(axes),         # live     (N,)    candidate mask, sharded
        )
    out_specs = (P(query_axis, None), P(query_axis, None), P(query_axis))
    if with_guards:
        out_specs = out_specs + (P(None),)     # replicated guard vector
    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(
        local_step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )


def make_distributed_search(
    mesh: Mesh,
    cfg: EngineConfig,
    *,
    data_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
    global_budget: bool = True,
    plan: VerificationPlan | None = None,
    jit: bool | str = "auto",
    with_guards: bool = False,
    with_sketch: bool = False,
):
    """Build a distributed search step for ``mesh``.

    Returns ``step(series, labels, upper, lower, kim, kim_ok, queries)``
    mapping sharded index leaves + queries to ``(dists, idx, n_dtw)`` with
    the query axis sharded over ``query_axis``.  Candidate indices in the
    output are *global* (shard offset applied).

    ``with_sketch`` appends the quantised sketch leaves to the input
    contract — ``step(..., queries, sk_lo, sk_hi, sk_scale, live)`` — so
    a store built with ``build_index(sketch=..., mask=True)`` serves its
    tier-(-1) bounds and candidate mask across the fleet (``_build_step``
    documents the sharding; pass ``live = ones(N, bool)`` when the store
    has features but no mask).

    ``global_budget`` (staged cascades only) swaps the per-shard local
    survivor budget for the mass-proportional global allocation described
    in the module docstring; ``False`` keeps fully-local compaction.

    ``plan`` overrides the default tier plan on every shard — this is how
    a ``calibrate_distributed_plan`` decision commits: the calibrated
    plan already carries the composed global-budget/refine-limit
    compaction, so it is used as-is.

    ``jit`` selects the degradation policy for the jax 0.4.x
    ``jit(shard_map(while))`` miscompile (module docstring):

      * ``"auto"`` (default): run ``guards.preflight_shard_map`` once per
        (mesh shape, axes, jax version) — exact canary gets the
        ``jax.jit``-wrapped step, a miscompiling one gets the safe
        unjitted step plus a once-per-process ``GuardWarning``;
      * ``True`` / ``False``: skip the canary and force the jitted /
        unjitted step (``True`` on a known-bad jax serves wrong results
        — it exists for the preflight test itself).

    ``with_guards`` appends the fleet-merged ``GuardReport`` *vector*
    (``GuardReport.from_vector`` restores the struct) as a fourth output:
    per-shard reports are psum/pmax-merged over the whole mesh inside the
    step, so every host sees one global report covering admissibility,
    conservation (including the shard-dropout echo check on the top-k
    all_gather), accounting, and finite gates.
    """
    step = _build_step(
        mesh, cfg, data_axes=data_axes, query_axis=query_axis,
        global_budget=global_budget, plan=plan, with_guards=with_guards,
        with_sketch=with_sketch,
    )
    if jit is False:
        return step
    if jit is True:
        return jax.jit(step)
    safe = _guards.preflight_shard_map(mesh, tuple(data_axes), query_axis)
    if safe:
        return jax.jit(step)
    _guards.warn_once(
        "jit_shard_map_while",
        "preflight: jit(shard_map) miscompiles the verification "
        f"while_loop on this jax ({jax.__version__}) — candidates are "
        "silently dropped; auto-selected the unjitted per-shard-compiled "
        "search step (exact, modestly slower dispatch)",
    )
    return step


def shard_index(mesh: Mesh, index: DTWIndex, data_axes=("data",)) -> DTWIndex:
    """Device-put an index with its N axis sharded over the data axes.

    The sketch store shards like every other per-candidate feature:
    ``sk_lo``/``sk_hi`` rows over the data axes, the store-wide
    ``sk_scale`` replicated, and the candidate mask ``live`` as a sharded
    vector.  Absent leaves stay ``None``.
    """
    axes = tuple(data_axes)
    row = NamedSharding(mesh, P(axes, None))
    vec = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    return DTWIndex(
        series=jax.device_put(index.series, row),
        labels=jax.device_put(index.labels, vec),
        upper=jax.device_put(index.upper, row),
        lower=jax.device_put(index.lower, row),
        kim=jax.device_put(index.kim, row),
        kim_ok=jax.device_put(index.kim_ok, row),
        w=index.w,
        sk_lo=(None if index.sk_lo is None
               else jax.device_put(index.sk_lo, row)),
        sk_hi=(None if index.sk_hi is None
               else jax.device_put(index.sk_hi, row)),
        sk_scale=(None if index.sk_scale is None
                  else jax.device_put(index.sk_scale, rep)),
        live=(None if index.live is None
              else jax.device_put(index.live, vec)),
    )
