"""Distributed NN-DTW search over a (pod, data, model) device mesh.

Sharding contract (DESIGN.md SS6):
  * the candidate store is sharded along its N axis over the *data* axes
    (``('data',)`` single-pod, ``('pod', 'data')`` multi-pod) — this is the
    axis that grows with corpus size, the paper's scaling bottleneck;
  * the query batch is sharded over the *model* axis — queries are
    independent, so this is embarrassing parallelism;
  * each device runs the full cascade + verification engine on its local
    shard, then the per-query top-k candidates are merged with a single
    ``all_gather`` over the data axes (k * n_data_shards values per query —
    tiny compared to the local work it summarises).

The communication volume is O(Q * k * shards) floats per search step —
independent of both N and L — so the collective roofline term stays
negligible at any corpus size (quantified in EXPERIMENTS.md SSRoofline).

Known limitation (jax 0.4.x): wrapping the returned step in an *outer*
``jax.jit`` miscompiles the engine's data-dependent verification
``while_loop`` under ``shard_map(check_rep=False)`` — results silently
drop candidates (reproduced against brute force at mesh (4, 2), N=256;
``check_rep=True`` is unavailable: 0.4.x has no replication rule for
``while``).  Call the returned step directly — it is already compiled
per-shard and exactness-tested by tests/test_distributed.py.  Tracked in
ROADMAP "Open items".
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.search.cascade import CascadeConfig
from repro.search.engine import EngineConfig, nn_search
from repro.search.index import DTWIndex

Array = jax.Array


def _axis_size(axis: str) -> Array:
    if hasattr(lax, "axis_size"):                      # jax >= 0.6
        return lax.axis_size(axis)
    return lax.psum(1, axis)                           # jax 0.4.x


def _combined_axis_index(axes: Sequence[str]) -> Array:
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_size(a) + lax.axis_index(a)
    return idx


def make_distributed_search(
    mesh: Mesh,
    cfg: EngineConfig,
    *,
    data_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
):
    """Build a jittable distributed search step for ``mesh``.

    Returns ``step(series, labels, upper, lower, kim, kim_ok, queries)``
    mapping sharded index leaves + queries to ``(dists, idx, n_dtw)`` with
    the query axis sharded over ``query_axis``.  Candidate indices in the
    output are *global* (shard offset applied).
    """
    axes = tuple(data_axes)

    def local_step(series, labels, upper, lower, kim, kim_ok, queries):
        index = DTWIndex(
            series=series, labels=labels, upper=upper, lower=lower,
            kim=kim, kim_ok=kim_ok, w=cfg.cascade.w,
        )
        res = nn_search(index, queries, cfg)
        n_local = series.shape[0]
        gidx = res.idx + (_combined_axis_index(axes) * n_local).astype(jnp.int32)
        # merge local top-k across the data axes
        d_all = lax.all_gather(res.dists, axes)        # (D, Qloc, k)
        i_all = lax.all_gather(gidx, axes)
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(res.dists.shape[0], -1)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(res.dists.shape[0], -1)
        k = res.dists.shape[1]
        neg, sel = lax.top_k(-d_flat, k)
        merged_d = -neg
        merged_i = jnp.take_along_axis(i_flat, sel, axis=1)
        n_dtw = lax.psum(res.n_dtw, axes)
        return merged_d, merged_i, n_dtw

    in_specs = (
        P(axes, None),   # series      (N, L)  sharded on N
        P(axes),         # labels      (N,)
        P(axes, None),   # upper       (N, L)
        P(axes, None),   # lower       (N, L)
        P(axes, None),   # kim         (N, 4)
        P(axes, None),   # kim_ok      (N, 2)
        P(query_axis, None),  # queries (Q, L) sharded on Q
    )
    out_specs = (P(query_axis, None), P(query_axis, None), P(query_axis))
    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(
        local_step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )


def shard_index(mesh: Mesh, index: DTWIndex, data_axes=("data",)) -> DTWIndex:
    """Device-put an index with its N axis sharded over the data axes."""
    axes = tuple(data_axes)
    row = NamedSharding(mesh, P(axes, None))
    vec = NamedSharding(mesh, P(axes))
    return DTWIndex(
        series=jax.device_put(index.series, row),
        labels=jax.device_put(index.labels, vec),
        upper=jax.device_put(index.upper, row),
        lower=jax.device_put(index.lower, row),
        kim=jax.device_put(index.kim, row),
        kim_ok=jax.device_put(index.kim_ok, row),
        w=index.w,
    )
