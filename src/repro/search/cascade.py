"""Lower-bound cascade: the tier-pipeline executor.

DESIGN — vocabulary (defined in search/pipeline.py, executed here):

  * **tier** (``BoundTier``): one bound stage with a *cost class* and a
    *scope*.  The default plan is the paper's cascade expressed as data:

      tier "sketch"             O(S)/pair    all_pairs  int8 PAA features
                                (tier -1, ``cfg.use_sketch`` — reads the
                                quantised feature store, never the series)
      tier "kim"                O(1)/pair    all_pairs  index features
      tier "bands"              O(V^2)/pair  all_pairs  bands (Alg. 1 1-11)
      tier "enhanced_pairwise"  O(L)/pair    pairwise   bands+Keogh bridge

    Every tier is a valid lower bound, so the *running elementwise max* of
    the executed tiers is the tightest available bound per pair — a loose
    or reordered tier changes work, never correctness.
  * **plan** (``VerificationPlan``): the ordered tier list + compaction +
    verification schedule.  Adding a tier (a second bands pass at another
    ``V``, a two-pass LB a la Lemire arXiv:0811.3301) or reordering tiers
    is a plan edit — see pipeline.py's module docstring for the worked
    ``register_tier`` example — not a cascade rewrite.
  * **compaction** (``Compaction``): the single gather point between the
    all-pairs and pairwise tiers: the ``B`` best-bounded candidates per
    query (ascending running bound) are packed into dense ``(Q*chunk, L)``
    row batches.  A ``limit_fn`` policy may cap, per query, how many packed
    slots the pairwise tiers refine (the *global survivor budget*:
    search/distributed.py all-gathers per-shard tier-0/1 minima inside its
    ``limit_fn`` and returns each shard's mass-proportional share).
    Unrefined slots keep their all-pairs bound — still valid, so the
    policy trades bound tightness for tier work, never exactness.
  * **schedule**: how the engine orders each verification round's flat
    (query, candidate) batch — ``"bound"`` argsorts ascending by tightest
    bound so doomed pairs cluster into the same DTW pair tiles (see
    engine.py), ``"index"`` keeps the unsorted stripe packing.

Pipeline (``run_plan``):

  1. all-pairs tiers in plan order, running max (O(Q*N) .. O(Q*N*V^2));
  2. gather-compact the most promising ``B`` candidates per query into
     packed batches (static budget, so the pipeline stays jit/shard_map-
     traceable), optionally capped per query by the compaction policy;
  3. pairwise tiers on the packed survivors only (O(Q*B*L) instead of
     O(Q*N*L)), scatter-maxed back into the bound matrix;
  4. *provisional k-th best*: verify the k best-bounded candidates per
     query with banded DTW — their k-th best distance ``tau`` upper-bounds
     the final k-th best, so the engine starts its loop already knowing
     that any pair whose bound exceeds ``tau`` can never enter the top-k
     (and threads ``tau`` into the DTW kernel's early-abandon cutoff).

DESIGN — two LB_ENHANCED kernel shapes, and which scope picks each:

  * **cross-block** (kernels/lb_enhanced.py): ``(TQ, L) x (TC, L) ->
    (TQ, TC)``.  ``all_pairs`` tiers are genuinely all-pairs — every query
    meets every candidate — so the block shape *is* the work
    (``bands_prefilter``/``enhanced_all_pairs`` route here).
  * **pairwise** (kernels/lb_enhanced_pairwise.py): packed ``(P, L)``
    query/candidate/envelope batches -> ``(P,)``.  Compacted survivors are
    (query, candidate) *pairs* — the diagonal of a cross block — so
    ``pairwise`` tiers route here (``cfg.pairwise_fn``): one VMEM round
    trip per pair tile instead of a ``TQ x TC`` block per ``min(TQ, TC)``
    useful answers.  This packed layout is also what the engine's flat
    verification scheduler and the DTW kernel's pair tiles consume, so
    everything downstream of compaction shares one shape — including the
    distributed path's globally-budgeted batches.

Survivor budget (step 2): budgets come from a static set of power-of-two
buckets (>= 64), so jit sees at most O(log N) distinct shapes.  When the
inputs are concrete, ``choose_survivor_budget`` picks the bucket from the
observed tier-0/1 pruning mass (how many candidates' cheap bounds fall
below a verified upper bound on the k-th best); under tracing the static
rule ``bucket(max(64, 4k, N/8))`` applies.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ref as kref
from repro.kernels.ops import (
    dtw_band_op,
    lb_enhanced_op,
    lb_enhanced_pairwise_op,
)
from repro.kernels.ref import dtw_band_ref
from repro.search import guards as _guards
from repro.search.index import DTWIndex, kim_features
from repro.search.pipeline import (
    TierStats,
    VerificationPlan,
    bucket_pow2,
    default_plan,
    dense_plan,
    tier_cost_weight,
)

Array = jax.Array

_INF = jnp.inf

# Survivor budgets are drawn from power-of-two buckets (floor 64) so the
# compacted tier shapes — and therefore jit recompilations — stay bounded
# at O(log N) regardless of how the adaptive selection moves between calls.
_BUDGET_FLOOR = 64


def _bucket_up(x: int) -> int:
    """Round ``x`` up to the next power-of-two budget bucket (>= 64)."""
    return bucket_pow2(x, _BUDGET_FLOOR)


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Static configuration of the pruning cascade.

    Attributes:
      w: Sakoe-Chiba window.
      v: LB_ENHANCED speed-tightness parameter (paper SS III-A); the paper's
         recommended V=4 is the default.
      use_kim: include the O(1) Kim tier in the default plans.
      use_sketch: prepend the tier-(-1) quantised sketch tier to the
        default plans (pipeline.py).  Off by default: the tier only pays
        on an index built with sketch features (``build_index`` computes
        them by default) — without features it scores an all-zero bound
        that the planner measures idle and drops.
      candidate_chunk: candidates per fused-kernel invocation (VMEM tiling).
      use_pallas: route the bound tiers through the Pallas kernels (True) or
        the pure-jnp references (False).  The jnp path is used when lowering
        the distributed search for the multi-pod dry-run, where kernel
        dispatch is orthogonal to the sharding being validated.
      staged: engine uses the staged tier pipeline (``run_plan`` over the
        default plan) instead of dense full-tier bounds.
      survivor_budget: per-query compaction width; ``None`` derives a
        power-of-two bucket from ``max(64, 4k, N/8)`` (clamped to N).  Must
        stay static for tracing.
      adaptive_budget: with ``survivor_budget=None`` and concrete (host)
        inputs, let the engine pick the bucket from the observed tier-0/1
        pruning mass (``choose_survivor_budget``) instead of the static
        rule.  Under tracing the static rule silently applies.
    """

    w: int
    v: int = 4
    use_kim: bool = True
    use_sketch: bool = False
    candidate_chunk: int = 512
    use_pallas: bool = True
    staged: bool = True
    survivor_budget: int | None = None
    adaptive_budget: bool = True

    def lb_fn(self):
        return lb_enhanced_op if self.use_pallas else kref.lb_enhanced_ref

    def pairwise_fn(self):
        """Pairwise-tier refinement over packed (P, L) survivor rows."""
        return (
            lb_enhanced_pairwise_op
            if self.use_pallas
            else kref.lb_enhanced_pairwise_ref
        )

    def dtw_fn(self):
        return dtw_band_op if self.use_pallas else dtw_band_ref

    def budget(self, n: int, k: int = 1) -> int:
        if self.survivor_budget is not None:
            return max(1, min(n, self.survivor_budget))
        return min(n, _bucket_up(max(_BUDGET_FLOOR, 4 * k, -(-n // 8))))


@dataclasses.dataclass(frozen=True)
class CascadeResult:
    """Tier-pipeline output consumed by the engine.

    Attributes:
      lb: (Q, N) per-pair lower bounds (all-pairs tiers everywhere,
        pairwise tiers on the compacted survivors, exact DTW at the seeds).
      seed_idx: (Q, k) candidate ids verified for the provisional threshold.
      seed_d: (Q, k) their exact banded-DTW distances.
      stats: measured per-tier pricing (``TierStats``) when the plan was
        executed with ``collect_stats=True`` — the planner's input;
        ``None`` otherwise.
      guard: the executor's ``GuardReport`` (admissibility seed
        spot-check, compaction conservation, finite gates) when guards
        ran; ``None`` when disabled.
    """

    lb: Array
    seed_idx: Array
    seed_d: Array
    stats: TierStats | None = None
    guard: _guards.GuardReport | None = None


def lb_kim_tier(q: Array, index: DTWIndex) -> Array:
    """(Q, N) Kim bounds from precomputed features — O(1) per pair."""
    qf, qok = kim_features(q)                        # (Q, 4), (Q, 2)
    cf, cok = index.kim, index.kim_ok                # (N, 4), (N, 2)
    d = qf[:, None, :] - cf[None, :, :]              # (Q, N, 4)
    d = d * d
    base = d[..., 0] + d[..., 1]
    # witness interiority: the series with the more extreme extremum
    q_mx, c_mx = qf[:, None, 2], cf[None, :, 2]
    ok_max = jnp.where(q_mx >= c_mx, qok[:, None, 0], cok[None, :, 0])
    t_max = jnp.where(ok_max, d[..., 2], 0.0)
    q_mn, c_mn = qf[:, None, 3], cf[None, :, 3]
    ok_min = jnp.where(q_mn <= c_mn, qok[:, None, 1], cok[None, :, 1])
    t_min = jnp.where(ok_min, d[..., 3], 0.0)
    return base + jnp.maximum(t_max, t_min)


def _chunked(
    fn, n: int, chunk: int
):
    """Map ``fn(start)`` over candidate chunks; concatenate on axis 1."""
    outs = [fn(s) for s in range(0, n, chunk)]
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def _accepts_kw(fn, name: str) -> bool:
    """Whether ``fn`` takes the keyword ``name`` (or ``**kwargs``).

    The executor's newer hooks are optional keywords — ``live`` on
    pairwise tier fns, ``tile_p`` on the DTW dispatch — and custom
    callbacks written to the older positional contracts must keep
    working: they get the plain call and the executor's own fallbacks
    (the belt mask below, the kernel-default tile) cover the rest.
    """
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):   # builtins/partials without signatures
        return False
    return name in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


def _accepts_live(fn) -> bool:
    return _accepts_kw(fn, "live")


def choose_survivor_budget(
    q: Array,
    index: DTWIndex,
    cfg: CascadeConfig,
    k: int = 1,
    *,
    exclude: Array | None = None,
    sample: int = 8,
    safety: float = 2.0,
) -> int:
    """Pick a power-of-two survivor budget from tier-0/1 pruning mass.

    Host-side (concrete inputs required): runs the cheap all-pairs tiers on
    a small query sample, verifies each sample query's ``k`` best-bounded
    candidates with banded DTW — their worst distance ``tau`` upper-bounds
    that query's final k-th best — and counts candidates whose cheap bound
    falls below ``tau``.  That count is the survivor mass the compaction
    budget must cover for the pairwise tiers to reach every candidate the
    engine could still verify; the max over the sample (times ``safety``)
    is rounded up to the next power-of-two bucket, so jit sees at most
    O(log N) distinct compacted shapes across calls (bounded
    recompilation).  The result is capped at 4x the static rule's bucket:
    on loose-bound data the mass estimate approaches N, and an uncapped
    budget would silently restore the dense tier cost the pipeline exists
    to avoid.

    ``exclude`` mirrors ``nn_search``'s per-query leave-one-out exclusion;
    without it a self-match candidate yields ``tau = 0`` and collapses the
    estimate to the floor.

    Cost: one cheap-tier pass over the sample plus ``S * k`` uncut DTW
    verifications, and a host sync on the mass count.  The engine memoises
    the chosen bucket per (index, k, w, config) — see
    ``pipeline.resolve_adaptive_budget`` — so repeated searches pay this
    once; the sample DTWs are estimator overhead outside the ``n_dtw``
    pruning-power metric (which counts the verification loop only).

    Raises ``jax.errors.ConcretizationTypeError`` under tracing — callers
    (engine.py) catch tracers beforehand and keep the static bucketed rule.
    """
    n = index.n
    k = min(k, n)
    q = jnp.asarray(q, jnp.float32)
    S = min(sample, q.shape[0])
    qs = q[:S]
    kim = (
        lb_kim_tier(qs, index) if cfg.use_kim
        else jnp.zeros((S, n), qs.dtype)
    )
    lb01 = jnp.maximum(kim, bands_prefilter(qs, index, cfg))
    if exclude is not None:
        lb01 = lb01.at[jnp.arange(S), exclude[:S]].set(_INF)
    _, cand = lax.top_k(-lb01, k)                    # (S, k) best-bounded
    qrep = jnp.repeat(qs, k, axis=0)
    d = cfg.dtw_fn()(qrep, index.series[cand.reshape(-1)], cfg.w)
    tau = jnp.max(d.reshape(S, k), axis=1, keepdims=True)
    mass = jnp.sum(lb01 < tau, axis=1)               # per-query survivors
    need = int(jnp.max(mass))                        # host sync (concrete)
    static_cap = 4 * _bucket_up(max(_BUDGET_FLOOR, 4 * k, -(-n // 8)))
    base = min(max(_BUDGET_FLOOR, 4 * k, int(need * safety)), static_cap)
    return min(n, _bucket_up(base))


def compute_bounds(
    q: Array,
    index: DTWIndex,
    cfg: CascadeConfig,
    *,
    k: int = 1,
    plan: VerificationPlan | None = None,
) -> Array:
    """(Q, N) tightest-available lower bound for every (query, candidate).

    With ``cfg.staged`` this executes the (given or default) tier plan and
    returns its bound matrix; otherwise it runs the *dense* plan — every
    pair pays the full O(L) tier (the seed behaviour, kept for diagnostics
    and as the baseline the staged pipeline is property-tested against).
    Both paths are the same declarative machinery: a tier list folded with
    a running elementwise max.
    """
    if cfg.staged:
        return run_plan(q, index, cfg, plan=plan, k=k).lb
    q = jnp.asarray(q, jnp.float32)
    plan = plan if plan is not None else dense_plan(cfg)
    if plan.pairwise_tiers:
        raise ValueError(
            "dense (cfg.staged=False) bounds have no compaction stage to "
            "feed pairwise tiers "
            f"({[t.name for t in plan.pairwise_tiers]}); use a dense_plan "
            "or enable staging"
        )
    store_live = getattr(index, "live", None)
    lb = None
    for tier in plan.all_pairs_tiers:
        if store_live is not None and _accepts_live(tier.fn):
            t = tier.fn(q, index, cfg, live=store_live)
        else:
            t = tier.fn(q, index, cfg)
        lb = t if lb is None else jnp.maximum(lb, t)
    if lb is None:
        lb = jnp.zeros((q.shape[0], index.n), q.dtype)
    return lb


def enhanced_all_pairs(
    q: Array, index: DTWIndex, cfg: CascadeConfig,
    *, live: Array | None = None,
) -> Array:
    """(Q, N) dense O(L) LB_ENHANCED tier — the ``enhanced_dense`` tier's
    bound fn.  Chunked over candidates so each fused-kernel call matches
    the VMEM tiling documented in kernels/lb_enhanced.py.

    ``live`` (optional ``(N,)``) limit-masks the dense tier the way the
    refine limit masks the packed pairwise tiers: dead candidates come
    back ``-inf`` (the running-max identity) and fully-dead candidate
    tiles skip their compute in the kernel — the planner's lever for a
    cross-block tier whose mass does not pay everywhere.
    """
    n = index.n
    chunk = min(cfg.candidate_chunk, n)
    lb_fn = cfg.lb_fn()

    def tier2(s: int) -> Array:
        e = min(s + chunk, n)
        return lb_fn(
            q,
            index.series[s:e],
            index.upper[s:e],
            index.lower[s:e],
            cfg.w,
            cfg.v,
            live=None if live is None else live[s:e],
        )

    return _chunked(tier2, n, chunk)


def run_plan(
    q: Array,
    index: DTWIndex,
    cfg: CascadeConfig,
    plan: VerificationPlan | None = None,
    k: int = 1,
    dtw_fn: Callable | None = None,
    *,
    exclude: Array | None = None,
    collect_stats: bool = False,
    guards: "_guards.GuardConfig | None" = None,
) -> CascadeResult:
    """Execute a ``VerificationPlan``: all-pairs tiers -> compact ->
    pairwise tiers -> seed verification.

    Fully traceable (static compaction width), so it works under ``jit``
    and inside the distributed ``shard_map``.  ``exclude`` removes a
    per-query candidate (leave-one-out) from seeding and compaction; its
    bound entry is left untouched for the engine to mask.

    ``guards`` (``None`` = the default-on config; see search/guards.py)
    threads the exactness guards through the executor: finite gates on
    every tier output, conservation checks on the compaction gather and
    scatter-max, and the admissibility spot-check on the seed pairs
    (the seeds already carry exact DTW values, so the spot-check costs
    only comparisons).  The checks are pure jnp and never raise — the
    outcome lands in ``CascadeResult.guard``.  On clean finite data
    every gate is the identity, so guarded results are bit-equal to
    unguarded ones (property-tested; overhead priced by the
    ``guard_overhead_*`` bench rows).

    ``collect_stats`` makes the executor *instrumented*: it snapshots the
    running bound after every tier and, once the seeds fix the threshold
    ``tau`` (k-th seed distance), prices each tier — incremental realised
    pruning mass, pairs scored, cost-class-weighted work — into a
    ``TierStats`` on the result (the planner's measurement input, see
    search/planner.py).  The accounting is pure jnp reductions, so the
    instrumented executor still traces under jit/shard_map; the snapshots
    cost ``O(T)`` extra bound-matrix copies, which is why stats are
    opt-in calibration machinery, not an always-on path.
    """
    plan = plan if plan is not None else default_plan(cfg)
    q = jnp.asarray(q, jnp.float32)
    Q, L = q.shape
    n = index.n
    k = min(k, n)
    if dtw_fn is None:
        dtw_fn = cfg.dtw_fn()
    qarange = jnp.arange(Q)

    g = _guards.resolve_guards(guards)
    gon = g.enabled
    z32 = jnp.zeros((), jnp.float32)
    nf_bounds = nf_dtw = z32                       # finite-gate counters
    c_checked = c_viol = z32                       # conservation
    a_checked = a_viol = a_gap = z32               # admissibility

    # ---- all-pairs tiers, in plan order (running elementwise max) ------
    # The store-level candidate mask (index.live, derived from the sketch
    # store at build time — search/index.py) feeds liveness-conforming
    # cross-block tiers the same way the refine limit feeds pairwise
    # tiers: dead candidates come back -inf and whole-dead tiles skip
    # compute.  Tiers without ``live`` support (kim, sketch — the sketch
    # tier *derives* the mask and must never consume it) score everyone,
    # so every dead candidate keeps a finite cheap bound: the mask can
    # only remove work, never a neighbour (exactness argument in
    # search/index.py).
    store_live = getattr(index, "live", None)
    lb01 = None
    ap_snaps = []                      # running max after each tier (stats)
    ap_masked = []                     # which tiers saw the store mask
    hook_tier = _guards.fault_hook("tier_out")
    for tier in plan.all_pairs_tiers:
        masked = store_live is not None and _accepts_live(tier.fn)
        ap_masked.append(masked)
        if masked:
            t = tier.fn(q, index, cfg, live=store_live)
        else:
            t = tier.fn(q, index, cfg)
        if hook_tier is not None:
            t = hook_tier(t, tier.name)
        if gon and g.finite_gates:
            t, gated = _guards.finite_gate_bounds(t)
            nf_bounds = nf_bounds + gated
        lb01 = t if lb01 is None else jnp.maximum(lb01, t)
        if collect_stats:
            ap_snaps.append(lb01)
    if lb01 is None:
        lb01 = jnp.zeros((Q, n), q.dtype)

    pairwise_tiers = plan.pairwise_tiers
    if pairwise_tiers:
        # ---- compaction: gather the B most promising survivors ---------
        comp = plan.compaction
        B = comp.budget if comp.budget is not None else cfg.budget(n, k)
        B = max(1, min(n, B))
        sel_key = (
            lb01 if exclude is None
            else lb01.at[qarange, exclude].set(_INF)
        )
        if comp.limit_fn is None:
            W, limit = B, None
        else:
            # static packed width leaves headroom above the uniform budget
            # so the policy can over-allocate to a skewed shard; the
            # per-query limits are traced values, the shapes are not
            W = max(1, min(n, comp.width_scale * B))
            limit = jnp.clip(
                comp.limit_fn(sel_key, B, k), min(k, W), W
            ).astype(jnp.int32)
        _, cand = lax.top_k(-sel_key, W)             # ascending cheap bound
        hook_cand = _guards.fault_hook("compaction_cand")
        if hook_cand is not None:
            cand = hook_cand(cand)
        if gon and g.conservation:
            cc, cv = _guards.conservation_check(cand, n)
            c_checked, c_viol = c_checked + cc, c_viol + cv

        # ---- pairwise tiers on the packed survivor batches -------------
        chunk = min(cfg.candidate_chunk, W)
        cols = []
        pw_snaps = [[] for _ in pairwise_tiers]   # per-tier running max
        plive = None                   # live pair count under any masking
        for s in range(0, W, chunk):
            e = min(s + chunk, W)
            cidx = cand[:, s:e].reshape(-1)          # (Q * bc,)
            qf = jnp.repeat(q, e - s, axis=0)
            crows = index.series[cidx]
            urows = index.upper[cidx]
            lrows = index.lower[cidx]
            hook_rows = _guards.fault_hook("packed_rows")
            if hook_rows is not None:
                crows, urows, lrows = hook_rows(crows, urows, lrows)
            # per-slot liveness from this query's refine allocation: the
            # packed layout keeps one query's slots contiguous, so light
            # queries yield whole dead pair tiles and the tier kernels
            # skip them outright (dead slots come back -inf — the
            # identity of the scatter-max below, so unrefined slots keep
            # their cheap tier-0/1 bound).  The store-level mask ANDs in
            # per *candidate*: a dead-store slot is dead in every
            # query's allocation.
            slot = jnp.arange(s, e)[None, :]
            live2d = None if limit is None else (slot < limit[:, None])
            if store_live is not None:
                sl = store_live[cidx].reshape(Q, e - s)
                live2d = sl if live2d is None else (live2d & sl)
            live = None if live2d is None else live2d.reshape(-1)
            if live2d is not None:
                c = jnp.sum(live2d).astype(jnp.float32)
                plive = c if plive is None else plive + c
            pe = None
            for ti, tier in enumerate(pairwise_tiers):
                if live is not None and _accepts_live(tier.fn):
                    t = tier.fn(qf, crows, urows, lrows, cfg, live=live)
                else:   # no limit, or a pre-liveness custom tier
                    t = tier.fn(qf, crows, urows, lrows, cfg)
                if hook_tier is not None:
                    t = hook_tier(t, tier.name)
                if gon and g.finite_gates:
                    t, gated = _guards.finite_gate_bounds(t)
                    nf_bounds = nf_bounds + gated
                pe = t if pe is None else jnp.maximum(pe, t)
                if collect_stats:
                    # running pairwise max after this tier, dead slots at
                    # the -inf scatter-max identity (the belt mask keeps
                    # pre-liveness custom tiers honest here too)
                    snap = pe.reshape(Q, e - s)
                    if live2d is not None:
                        snap = jnp.where(live2d, snap, -_INF)
                    pw_snaps[ti].append(snap)
            block = pe.reshape(Q, e - s)
            if live2d is not None:
                # belt for tiers without ``live`` support: the mask is
                # idempotent over the kernel's own -inf dead slots
                block = jnp.where(live2d, block, -_INF)
            cols.append(block)
        enh = jnp.concatenate(cols, axis=1) if len(cols) > 1 else cols[0]
        lb = lb01.at[qarange[:, None], cand].max(enh)
        if gon and g.conservation:
            mc, mv = _guards.scatter_monotone_check(lb01, lb)
            c_checked, c_viol = c_checked + mc, c_viol + mv
    else:
        lb = lb01

    # ---- provisional k-th best: verify the k best-bounded candidates --
    # Seeds are picked from the *refined* bound order, so the k seed
    # verifications are exactly the first k verifications the engine's
    # ascending-bound loop would perform anyway — the threshold tier costs
    # no extra DTW, it only moves those verifications before the loop so
    # tau = k-th seed distance can warm-start pruning and cutoffs.
    seed_sel = lb if exclude is None else lb.at[qarange, exclude].set(_INF)
    _, seed_idx = lax.top_k(-seed_sel, k)            # (Q, k)
    qs = jnp.repeat(q, k, axis=0)                    # (Q*k, L)
    cs = index.series[seed_idx.reshape(-1)]
    # seeds are the tightest-bound pairs — almost all live, so the
    # per-round tile policy keeps full tiles here; an explicit plan
    # verify_tile_p still overrides (pipeline.py) when the dispatch
    # understands it (a custom dtw_fn on the old (a, b, w) contract gets
    # the plain call — tile size is packing geometry, never semantics)
    if plan.verify_tile_p is not None and _accepts_kw(dtw_fn, "tile_p"):
        seed_d = dtw_fn(qs, cs, cfg.w, tile_p=plan.verify_tile_p)
    else:
        seed_d = dtw_fn(qs, cs, cfg.w)
    seed_d = seed_d.reshape(Q, k)
    if gon and g.finite_gates:
        # a NaN seed DTW would poison tau and the engine's warm start:
        # gate it to +inf (unverifiable) and count the incident
        seed_d, gated = _guards.finite_gate_dtw(seed_d)
        nf_dtw = nf_dtw + gated
    if gon and g.admissibility:
        # the seeds *are* the sampled survivor pairs — their bound (the
        # running max before the exact value lands) must not exceed
        # their verified DTW; the comparison reuses values that already
        # exist, so the spot-check costs no extra DTW
        pre = jnp.take_along_axis(lb, seed_idx, axis=1)
        ac, av, ag = _guards.admissibility_check(pre, seed_d, g.rtol, g.atol)
        a_checked, a_viol = a_checked + ac, a_viol + av
        a_gap = jnp.maximum(a_gap, ag)
    # seed pairs are exactly verified: their distance is the perfect bound
    if gon and g.finite_gates:
        # a gated (+inf) seed must not poison the bound matrix — +inf
        # there means "never verify", the exact failure the gates exist
        # to prevent; the engine re-opens such seeds for verification
        lb = lb.at[qarange[:, None], seed_idx].max(
            jnp.where(jnp.isfinite(seed_d), seed_d, -_INF)
        )
    else:
        lb = lb.at[qarange[:, None], seed_idx].max(seed_d)

    stats = None
    if collect_stats:
        # ---- tier pricing against the seed-verified threshold ----------
        # tau upper-bounds each query's final k-th best, so a pair whose
        # running bound reaches tau is realised pruning; the crossing is
        # attributed to the tier whose fold first took it across.
        tau = jnp.max(seed_d, axis=1, keepdims=True)          # (Q, 1)
        excl = (
            None if exclude is None
            else jnp.arange(n)[None, :] == exclude[:, None]
        )

        def _crossed(prev, cur, emask):
            newly = (cur >= tau) & (prev < tau)
            if emask is not None:
                newly = newly & ~emask
            return jnp.sum(newly).astype(jnp.float32)

        # the sketch tier's "O(S)" cost class prices by the committed
        # segment count; tiers on an unsketched index keep the default
        s_sk = (
            int(index.sk_lo.shape[1])
            if getattr(index, "sk_lo", None) is not None else 16
        )
        names, costs, scopes = [], [], []
        mass, scored, work = [], [], []
        prev_ap = jnp.zeros((Q, n), q.dtype)
        n_live = (
            None if store_live is None
            else jnp.sum(store_live).astype(jnp.float32)
        )
        for i, tier in enumerate(plan.all_pairs_tiers):
            names.append(tier.name)
            costs.append(tier.cost)
            scopes.append(tier.scope)
            mass.append(_crossed(prev_ap, ap_snaps[i], excl))
            # a store-masked cross-block tier scores only live columns —
            # that is the work the planner prices
            sc = (
                jnp.asarray(float(Q), jnp.float32) * n_live
                if ap_masked[i]
                else jnp.asarray(float(Q * n), jnp.float32)
            )
            scored.append(sc)
            work.append(
                sc * tier_cost_weight(tier.cost, L, cfg.v, cfg.w, s_sk)
            )
            prev_ap = ap_snaps[i]
        if pairwise_tiers:
            base = lb01[qarange[:, None], cand]               # (Q, W)
            pexcl = None if exclude is None else cand == exclude[:, None]
            # under a refine limit a liveness-conforming tier scores only
            # its live slots — that is the work the planner prices, and
            # the belt mask holds pre-liveness custom tiers to the same
            # semantics
            pscored = (
                plive if plive is not None
                else jnp.asarray(float(Q * W), jnp.float32)
            )
            prev_pw = base
            for ti, tier in enumerate(pairwise_tiers):
                pe_full = (
                    jnp.concatenate(pw_snaps[ti], axis=1)
                    if len(pw_snaps[ti]) > 1 else pw_snaps[ti][0]
                )
                cur_pw = jnp.maximum(base, pe_full)
                names.append(tier.name)
                costs.append(tier.cost)
                scopes.append(tier.scope)
                mass.append(_crossed(prev_pw, cur_pw, pexcl))
                scored.append(pscored)
                work.append(
                    pscored
                    * tier_cost_weight(tier.cost, L, cfg.v, cfg.w, s_sk)
                )
                prev_pw = cur_pw
        surv_key = (
            lb01 if exclude is None
            else lb01.at[qarange, exclude].set(_INF)
        )
        survivors = jnp.sum(surv_key < tau, axis=1).astype(jnp.float32)
        zero = jnp.zeros((0,), jnp.float32)
        stats = TierStats(
            names=tuple(names),
            costs=tuple(costs),
            scopes=tuple(scopes),
            mass=jnp.stack(mass) if mass else zero,
            scored=jnp.stack(scored) if scored else zero,
            work=jnp.stack(work) if work else zero,
            pairs=jnp.asarray(
                float(Q * (n - 1 if exclude is not None else n)),
                jnp.float32,
            ),
            queries=jnp.asarray(float(Q), jnp.float32),
            survivors=survivors,
        )
    guard = None
    if gon:
        guard = dataclasses.replace(
            _guards.GuardReport.zeros(),
            admiss_checked=a_checked, admiss_viol=a_viol, admiss_gap=a_gap,
            conserve_checked=c_checked, conserve_viol=c_viol,
            nonfinite_bounds=nf_bounds, nonfinite_dtw=nf_dtw,
        )
    return CascadeResult(lb=lb, seed_idx=seed_idx, seed_d=seed_d,
                         stats=stats, guard=guard)


def staged_bounds(
    q: Array,
    index: DTWIndex,
    cfg: CascadeConfig,
    k: int = 1,
    dtw_fn: Callable | None = None,
    *,
    exclude: Array | None = None,
    plan: VerificationPlan | None = None,
) -> CascadeResult:
    """Execute the default (or given) staged tier plan — the historical
    entry point; ``run_plan`` is the general executor it wraps."""
    return run_plan(q, index, cfg, plan=plan, k=k, dtw_fn=dtw_fn,
                    exclude=exclude)


def bands_prefilter(
    q: Array, index: DTWIndex, cfg: CascadeConfig,
    *, live: Array | None = None,
) -> Array:
    """(Q, N) bands-only tier (Alg. 1 lines 1-11) — the cheap pre-bound.

    The ``bands`` tier's bound fn: picks compaction survivors before the
    pipeline pays for the O(L) bridge; on the roofline it is ~V^2/L of the
    pairwise tier.

    ``live`` (optional ``(N,)``) is the store-level candidate mask
    (search/index.py): dead candidates come back ``-inf`` and fully-dead
    candidate tiles skip their compute in the kernel.
    """
    n = index.n
    chunk = min(cfg.candidate_chunk, n)
    lb_fn = cfg.lb_fn()

    def tier1(s: int) -> Array:
        e = min(s + chunk, n)
        return lb_fn(
            q,
            index.series[s:e],
            index.upper[s:e],
            index.lower[s:e],
            cfg.w,
            cfg.v,
            live=None if live is None else live[s:e],
            bands_only=True,
        )

    return _chunked(tier1, n, chunk)
