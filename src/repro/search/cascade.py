"""Batched lower-bound cascade (TPU adaptation of UCR-suite cascading).

The paper's NN-DTW loop abandons candidates one at a time; a TPU wants the
same *work-skipping* expressed as dense tiers (DESIGN.md SS3):

  tier 0  LB_KIM        O(1)/pair   from precomputed index features
  tier 1  LB bands      O(V^2)/pair elastic bands only (Alg. 1 lines 1-11)
  tier 2  LB_ENHANCED   O(L)/pair   fused bands + Keogh bridge kernel

Every tier is a valid lower bound, so the *running elementwise max* of the
computed tiers is the tightest available bound per pair.  The cascade
returns that (Q, N) bound matrix; the engine (engine.py) then verifies
ascending-bound candidates with banded DTW until exactness is certified.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ref as kref
from repro.kernels.ops import lb_enhanced_op
from repro.search.index import DTWIndex, kim_features

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Static configuration of the pruning cascade.

    Attributes:
      w: Sakoe-Chiba window.
      v: LB_ENHANCED speed-tightness parameter (paper SS III-A); the paper's
         recommended V=4 is the default.
      use_kim: include the O(1) Kim tier.
      candidate_chunk: candidates per fused-kernel invocation (VMEM tiling).
      use_pallas: route tier 1/2 through the Pallas kernels (True) or the
        pure-jnp references (False).  The jnp path is used when lowering the
        distributed search for the multi-pod dry-run, where kernel dispatch
        is orthogonal to the sharding being validated.
    """

    w: int
    v: int = 4
    use_kim: bool = True
    candidate_chunk: int = 512
    use_pallas: bool = True

    def lb_fn(self):
        return lb_enhanced_op if self.use_pallas else kref.lb_enhanced_ref


def lb_kim_tier(q: Array, index: DTWIndex) -> Array:
    """(Q, N) Kim bounds from precomputed features — O(1) per pair."""
    qf, qok = kim_features(q)                        # (Q, 4), (Q, 2)
    cf, cok = index.kim, index.kim_ok                # (N, 4), (N, 2)
    d = qf[:, None, :] - cf[None, :, :]              # (Q, N, 4)
    d = d * d
    base = d[..., 0] + d[..., 1]
    # witness interiority: the series with the more extreme extremum
    q_mx, c_mx = qf[:, None, 2], cf[None, :, 2]
    ok_max = jnp.where(q_mx >= c_mx, qok[:, None, 0], cok[None, :, 0])
    t_max = jnp.where(ok_max, d[..., 2], 0.0)
    q_mn, c_mn = qf[:, None, 3], cf[None, :, 3]
    ok_min = jnp.where(q_mn <= c_mn, qok[:, None, 1], cok[None, :, 1])
    t_min = jnp.where(ok_min, d[..., 3], 0.0)
    return base + jnp.maximum(t_max, t_min)


def _chunked(
    fn, n: int, chunk: int
):
    """Map ``fn(start)`` over candidate chunks; concatenate on axis 1."""
    outs = [fn(s) for s in range(0, n, chunk)]
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def compute_bounds(q: Array, index: DTWIndex, cfg: CascadeConfig) -> Array:
    """(Q, N) tightest-available lower bound for every (query, candidate).

    Chunked over candidates so each fused-kernel call matches the VMEM
    tiling documented in kernels/lb_enhanced.py.
    """
    n = index.n
    chunk = min(cfg.candidate_chunk, n)
    lb_fn = cfg.lb_fn()

    def tier2(s: int) -> Array:
        e = min(s + chunk, n)
        return lb_fn(
            q,
            index.series[s:e],
            index.upper[s:e],
            index.lower[s:e],
            cfg.w,
            cfg.v,
        )

    lb = _chunked(tier2, n, chunk)
    if cfg.use_kim:
        lb = jnp.maximum(lb, lb_kim_tier(q, index))
    return lb


def bands_prefilter(q: Array, index: DTWIndex, cfg: CascadeConfig) -> Array:
    """(Q, N) bands-only tier (Alg. 1 lines 1-11) — the cheap pre-bound.

    Exposed separately so callers on real hardware can prune with it before
    paying for the O(L) bridge; on the roofline it is ~V^2/L of tier 2.
    """
    n = index.n
    chunk = min(cfg.candidate_chunk, n)
    lb_fn = cfg.lb_fn()

    def tier1(s: int) -> Array:
        e = min(s + chunk, n)
        return lb_fn(
            q,
            index.series[s:e],
            index.upper[s:e],
            index.lower[s:e],
            cfg.w,
            cfg.v,
            bands_only=True,
        )

    return _chunked(tier1, n, chunk)
