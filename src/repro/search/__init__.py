"""NN-DTW search engine: cascade pruning + exact verification."""

from repro.search.cascade import (
    CascadeConfig,
    CascadeResult,
    bands_prefilter,
    choose_survivor_budget,
    compute_bounds,
    staged_bounds,
)
from repro.search.distributed import make_distributed_search, shard_index
from repro.search.engine import (
    EngineConfig,
    SearchResult,
    brute_force,
    classify,
    nn_search,
)
from repro.search.index import DTWIndex, build_index, kim_features

__all__ = [
    "CascadeConfig",
    "CascadeResult",
    "DTWIndex",
    "EngineConfig",
    "SearchResult",
    "bands_prefilter",
    "brute_force",
    "build_index",
    "choose_survivor_budget",
    "classify",
    "compute_bounds",
    "kim_features",
    "make_distributed_search",
    "nn_search",
    "shard_index",
    "staged_bounds",
]
