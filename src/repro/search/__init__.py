"""NN-DTW search engine: tier-pipeline pruning + exact verification."""

from repro.search.cascade import (
    CascadeConfig,
    CascadeResult,
    bands_prefilter,
    choose_survivor_budget,
    compute_bounds,
    enhanced_all_pairs,
    run_plan,
    staged_bounds,
)
from repro.search.distributed import make_distributed_search, shard_index
from repro.search.engine import (
    EngineConfig,
    SearchResult,
    brute_force,
    classify,
    nn_search,
)
from repro.search.index import DTWIndex, build_index, kim_features
from repro.search.pipeline import (
    BoundTier,
    Compaction,
    VerificationPlan,
    default_plan,
    dense_plan,
    get_tier,
    register_tier,
    registered_tiers,
)

__all__ = [
    "BoundTier",
    "CascadeConfig",
    "CascadeResult",
    "Compaction",
    "DTWIndex",
    "EngineConfig",
    "SearchResult",
    "VerificationPlan",
    "bands_prefilter",
    "brute_force",
    "build_index",
    "choose_survivor_budget",
    "classify",
    "compute_bounds",
    "default_plan",
    "dense_plan",
    "enhanced_all_pairs",
    "get_tier",
    "kim_features",
    "make_distributed_search",
    "nn_search",
    "register_tier",
    "registered_tiers",
    "run_plan",
    "shard_index",
    "staged_bounds",
]
