"""Exact NN-DTW search engine with lower-bound pruning.

TPU adaptation of the paper's sequential early-abandon NN loop
(DESIGN.md SS3): instead of visiting candidates one at a time, the engine

  1. computes the (Q, N) cascade bound matrix (cascade.py),
  2. sorts candidates per query by ascending bound (UCR-suite ordering),
  3. verifies banded DTW in fixed-size *rounds* of ``verify_chunk``
     candidates, maintaining a per-query top-k, and
  4. stops a query as soon as its k-th best verified DTW is <= the smallest
     unverified bound — an *exactness certificate*: no remaining candidate
     can displace the current top-k, because bounds never exceed true DTW.

The result is exact (identical neighbours to brute force — property-tested)
and the number of verified candidates matches what the paper's pruning-power
metric counts: ``P = 1 - n_dtw / N``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ops import dtw_band_op
from repro.kernels.ref import dtw_band_ref
from repro.search.cascade import CascadeConfig, compute_bounds
from repro.search.index import DTWIndex

Array = jax.Array

_INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Exact k-NN under DTW_w plus pruning accounting.

    Attributes:
      dists: (Q, k) squared-cost DTW distances, ascending.
      idx:   (Q, k) candidate indices into the store.
      n_dtw: (Q,) number of DTW verifications actually performed.
      lb:    (Q, N) the cascade bound matrix (for diagnostics/benchmarks).
    """

    dists: Array
    idx: Array
    n_dtw: Array
    lb: Array

    def pruning_power(self, n: int | None = None) -> Array:
        n = n if n is not None else self.lb.shape[1]
        return 1.0 - self.n_dtw / n


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs on top of the cascade config.

    Attributes:
      cascade: the lower-bound cascade configuration.
      verify_chunk: DTW verifications per round (the TPU batch analogue of
        the paper's one-at-a-time loop; each round is one fused kernel
        launch of ``Q * verify_chunk`` banded-DTW lane problems).
      k: neighbours to return.
    """

    cascade: CascadeConfig
    verify_chunk: int = 32
    k: int = 1


def nn_search(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    exclude: Array | None = None,
) -> SearchResult:
    """Exact k-NN-DTW for a batch of queries.

    Args:
      index: candidate store (build_index).
      queries: (Q, L) query batch.
      cfg: engine config; ``cfg.cascade.w`` is the DTW window.
      exclude: optional (Q,) candidate index to exclude per query
        (leave-one-out evaluation).
    """
    q = jnp.asarray(queries, jnp.float32)
    Q, L = q.shape
    N = index.n
    k = min(cfg.k, N)
    M = min(cfg.verify_chunk, N)
    w = cfg.cascade.w
    dtw_fn = dtw_band_op if cfg.cascade.use_pallas else dtw_band_ref

    lb = compute_bounds(q, index, cfg.cascade)            # (Q, N)
    if exclude is not None:
        lb = lb.at[jnp.arange(Q), exclude].set(_INF)

    # ---- work-conserving flat verification scheduler -------------------
    # The naive per-query round scheme wastes whole rounds on finished
    # queries (one ambiguous straggler forces Q*M DTWs per extra round).
    # Instead each round builds a flat batch of P = Q*M (query, candidate)
    # slots striped over the *undone* queries only: every undone query
    # receives a uniform quota = min(P // n_undone, T_max) of its next
    # unverified ranks, so stragglers soak up the slots finished queries
    # no longer need (up to the static gather cap T_max = 8*M).  Total DTW
    # compute tracks the semantic verified count instead of rounds*Q*M.
    order = jnp.argsort(lb, axis=1)                       # (Q, N)
    slb = jnp.take_along_axis(lb, order, axis=1)
    slb_pad = jnp.pad(slb, ((0, 0), (0, 1)), constant_values=_INF)
    P = Q * M
    T_max = min(N, 8 * M)
    qarange = jnp.arange(Q)
    jarange = jnp.arange(P)
    max_rounds = -(-Q * N // P) + 2

    def body(state):
        r, best_d, best_i, n_dtw, cursor, done = state
        n_un = jnp.maximum(jnp.sum(~done), 1)
        quota = jnp.minimum(P // n_un, T_max)             # ranks per query
        qorder = jnp.argsort(done)                        # undone first
        pos = jnp.argsort(qorder)                         # query -> stripe
        qi = qorder[jarange % n_un]                       # (P,) slot query
        stripe = jarange // n_un
        rank = cursor[qi] + stripe
        valid = (~done[qi]) & (rank < N) & (stripe < quota)
        rank_c = jnp.minimum(rank, N - 1)
        cidx = order[qi, rank_c]                          # candidate ids
        lbv = jnp.where(valid, slb[qi, rank_c], _INF)
        kth0 = best_d[:, k - 1]
        active = valid & (lbv < kth0[qi])                 # semantic count
        d = dtw_fn(q[qi], index.series[cidx], w)          # (P,) flat
        d = jnp.where(valid, d, _INF)
        n_dtw = n_dtw + jax.ops.segment_sum(
            active.astype(jnp.int32), qi, num_segments=Q
        )
        # per-query gather of this round's results (stripe layout)
        t = jnp.arange(T_max)
        slots = pos[:, None] + t[None, :] * n_un          # (Q, T_max)
        ok = (t[None, :] < quota) & (slots < P)
        slots_c = jnp.minimum(slots, P - 1)
        gd = jnp.where(ok & (qi[slots_c] == qarange[:, None]),
                       d[slots_c], _INF)
        gi = cidx[slots_c]
        alld = jnp.concatenate([best_d, gd], axis=1)
        alli = jnp.concatenate([best_i, gi], axis=1)
        neg, sel = lax.top_k(-alld, k)
        best_d = -neg
        best_i = jnp.take_along_axis(alli, sel, axis=1)
        cursor = jnp.minimum(cursor + jnp.where(~done, quota, 0), N)
        next_lb = slb_pad[qarange, cursor]
        done = done | (best_d[:, k - 1] <= next_lb) | (cursor >= N)
        return r + 1, best_d, best_i, n_dtw, cursor, done

    def cond(state):
        r, _, _, _, _, done = state
        return (r < max_rounds) & ~jnp.all(done)

    state = (
        jnp.int32(0),
        jnp.full((Q, k), _INF, jnp.float32),
        jnp.full((Q, k), -1, jnp.int32),
        jnp.zeros((Q,), jnp.int32),
        jnp.zeros((Q,), jnp.int32),
        jnp.zeros((Q,), bool),
    )
    _, best_d, best_i, n_dtw, _, _ = lax.while_loop(cond, body, state)
    return SearchResult(dists=best_d, idx=best_i, n_dtw=n_dtw, lb=lb)


def classify(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    exclude: Array | None = None,
) -> tuple[Array, SearchResult]:
    """k-NN-DTW classification: majority vote over the k neighbours."""
    res = nn_search(index, queries, cfg, exclude=exclude)
    votes = index.labels[res.idx]                                     # (Q, k)
    n_cls = int(jnp.max(index.labels)) + 1 if index.labels.size else 1
    counts = jax.vmap(
        lambda v: jnp.bincount(v, length=max(n_cls, 1))
    )(jnp.maximum(votes, 0))
    pred = jnp.argmax(counts, axis=1)
    return pred, res


def brute_force(
    index: DTWIndex, queries: Array, w: int, k: int = 1,
    *, exclude: Array | None = None, use_pallas: bool = True,
) -> tuple[Array, Array]:
    """Unpruned exact k-NN (the O(N * L * W) baseline the paper speeds up)."""
    q = jnp.asarray(queries, jnp.float32)
    Q, L = q.shape
    N = index.n
    dtw_fn = dtw_band_op if use_pallas else dtw_band_ref
    qrep = jnp.broadcast_to(q[:, None, :], (Q, N, L)).reshape(Q * N, L)
    crep = jnp.broadcast_to(index.series[None], (Q, N, L)).reshape(Q * N, L)
    d = dtw_fn(qrep, crep, w).reshape(Q, N)
    if exclude is not None:
        d = d.at[jnp.arange(Q), exclude].set(_INF)
    neg, idx = lax.top_k(-d, min(k, N))
    return -neg, idx
