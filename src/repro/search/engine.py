"""Exact NN-DTW search engine with lower-bound pruning.

TPU adaptation of the paper's sequential early-abandon NN loop
(DESIGN.md SS3): instead of visiting candidates one at a time, the engine

  1. computes per-pair lower bounds by executing the verification plan's
     tier pipeline (cascade.run_plan): all-pairs tiers -> compaction ->
     pairwise tiers -> k verified seeds (or the dense full-tier matrix
     when ``cascade.staged`` is off),
  2. warm-starts the per-query top-k from the verified seeds and sorts the
     remaining candidates by ascending bound (UCR-suite ordering),
  3. verifies banded DTW in fixed-size *rounds*, threading each query's
     current k-th best distance into the kernel's per-pair ``cutoff`` so
     hopeless lanes abandon early (PrunedDTW-style), and
  4. stops a query as soon as its k-th best verified DTW is <= the smallest
     unverified bound — an *exactness certificate*: no remaining candidate
     can displace the current top-k, because bounds never exceed true DTW.

Bound-ordered verification schedule (``plan.schedule == "bound"``): each
round's flat batch of (query, candidate) slots is argsorted ascending by
its tightest bound *before* packing into the DTW kernel's pair tiles; the
engine composes the permutation into its slot->row gathers and scatters
the (P,) results back (kernels/tiling.py — external callers get the same
packing via the ops' ``perm=`` gather), so downstream accounting sees the
original slot order.  The kernel's row-block early exit skips a tile's
remaining anti-diagonal blocks only when *every* lane in the tile is
abandoned — under the unsorted stripe packing a doomed pair almost always
shares its tile with a live one, so the exit rarely fires.  Sorting
clusters the doomed pairs (loosest bounds, Herrmann & Webb's early-abandon
ordering, arXiv:2102.05221) into the same tiles, converting the per-tile
exit into an effective per-pair early exit.  The permutation changes
*packing only*: per-lane DTW values are independent of tile composition,
so results are bit-identical and per-query ``n_dtw`` (computed in slot
order from the same values) is unchanged — property-tested against the
``"index"`` schedule and brute force.

The cutoff never changes results: a lane abandons only when its frontier
minimum proves the true distance exceeds the query's current k-th best, so
the abandoned candidate could not have entered the top-k anyway.

The result is exact (identical neighbours to brute force — property-tested)
and the number of verified candidates matches what the paper's pruning-power
metric counts: ``P = 1 - n_dtw / N``.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels.ops import dtw_band_op
from repro.kernels.ref import dtw_band_ref
from repro.kernels.tiling import sched_pair_tile, unpermute_pairs
from repro.search import guards as _g
from repro.search import planner as _planner
from repro.search.cascade import (
    CascadeConfig,
    compute_bounds,
    run_plan,
)
from repro.search.index import DTWIndex
from repro.search.pipeline import (
    TierStats,
    VerificationPlan,
    default_plan,
    dense_plan,
    resolve_adaptive_budget,
)
from repro.search.planner import PlannerConfig

Array = jax.Array

_INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Exact k-NN under DTW_w plus pruning accounting.

    Attributes:
      dists: (Q, k) squared-cost DTW distances, ascending.
      idx:   (Q, k) candidate indices into the store.
      n_dtw: (Q,) number of DTW verifications actually performed.
      lb:    (Q, N) the cascade bound matrix (for diagnostics/benchmarks).
    """

    dists: Array
    idx: Array
    n_dtw: Array
    lb: Array

    def pruning_power(self, n: int | None = None) -> Array:
        n = n if n is not None else self.lb.shape[1]
        return 1.0 - self.n_dtw / n


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs on top of the cascade config.

    Attributes:
      cascade: the lower-bound cascade configuration.
      verify_chunk: DTW verifications per round (the TPU batch analogue of
        the paper's one-at-a-time loop; each round is one fused kernel
        launch of ``Q * verify_chunk`` banded-DTW lane problems).
      k: neighbours to return.
      auto_plan: calibrate-then-commit (staged cascades, concrete inputs
        only): a cold search runs its first query block under the base
        plan with the instrumented executor, hands the measured
        ``TierStats`` to the planner, and runs every remaining block —
        and every later search against the same store/config — under the
        committed optimised plan (search/planner.py).  Results are
        bit-equal by construction: the planner only removes bound work,
        and unrefined pairs keep a valid looser bound.  Under tracing the
        flag is inert (the base plan runs unchanged), like the adaptive
        budget.
      planner: decision thresholds for the commit (``None`` =
        ``PlannerConfig()`` defaults).
      guards: exactness-guard configuration (search/guards.py).  ``None``
        means the *default-on* ``GuardConfig()`` — admissibility spot
        checks, conservation, accounting and finite gates all run (their
        overhead is priced and CI-bounded; see the ``guard_overhead_*``
        bench rows).  Pass ``GuardConfig(enabled=False)`` to opt out;
        ``REPRO_FORCE_GUARDS=1`` in the environment overrides everything
        on.
    """

    cascade: CascadeConfig
    verify_chunk: int = 32
    k: int = 1
    auto_plan: bool = False
    planner: PlannerConfig | None = None
    guards: _g.GuardConfig | None = None


@dataclasses.dataclass(frozen=True)
class SearchStats:
    """Public pruning report for one search (host-side).

    The paper's Fig.-style pruning-power readout as an API: which tiers
    the committed plan ran, what each measured tier bought (realised
    pruning mass vs cost-weighted work), what the planner decided, and
    what the engine verified.  Produced by ``nn_search(...,
    with_stats=True)``; ``table()`` renders the per-tier table the
    examples print.

    Attributes:
      tiers: the measured ``TierStats`` (base-plan pricing when the
        search calibrated, the executed plan's pricing otherwise).
      plan_tiers: committed tier names, in committed order.
      schedule: committed verification schedule.
      dropped: tiers the planner removed (empty without ``auto_plan``).
      budget / limit: committed compaction bucket / refine limit
        (``None`` = untouched).
      calibrated: whether a planner decision produced the committed plan.
      n_dtw: (Q,) DTW verifications per query.
      n: store size (the pruning-power denominator).
      guards: the merged ``GuardReport`` (cascade + engine) for the
        search, ``None`` when guards were disabled.
      degraded: whether a tripped guard forced the degradation-ladder
        fallback to reference brute force (the returned result is the
        fallback's).
    """

    tiers: TierStats
    plan_tiers: tuple[str, ...]
    schedule: str
    dropped: tuple[str, ...]
    budget: int | None
    limit: int | None
    calibrated: bool
    n_dtw: Array
    n: int
    guards: "_g.GuardReport | None" = None
    degraded: bool = False

    def pruning_power(self) -> Array:
        return 1.0 - np.asarray(self.n_dtw) / self.n

    def table(self) -> str:
        nd = np.asarray(self.n_dtw)
        lines = [self.tiers.table(), "-" * 78]
        commit = f"plan: {' -> '.join(self.plan_tiers) or '<no tiers>'} " \
                 f"[{self.schedule}]"
        if self.dropped:
            commit += f"   dropped: {', '.join(self.dropped)}"
        if self.budget is not None:
            commit += f"   budget={self.budget}"
        if self.limit is not None:
            commit += f"   limit={self.limit}"
        if self.calibrated:
            commit += "   (planner-committed)"
        lines.append(commit)
        lines.append(
            f"n_dtw: {int(nd.sum())} of {nd.size * self.n} pairs verified "
            f"(mean pruning power {float(np.mean(self.pruning_power())):.1%})"
        )
        if self.guards is not None:
            gline = self.guards.summary()
            if self.degraded:
                gline += "   [DEGRADED: reference brute force served]"
            lines.append(gline)
        return "\n".join(lines)


def _all_concrete(q: Array, index: DTWIndex,
                  exclude: Array | None) -> bool:
    """Whether every search input is a concrete (host) value.

    The one definition behind both host-only gates — the adaptive budget
    estimate and the planner's calibrate-then-commit — so they always
    defer under tracing together."""
    return not (
        isinstance(q, jax.core.Tracer)
        or isinstance(index.series, jax.core.Tracer)
        or isinstance(exclude, jax.core.Tracer)
    )


def _resolve_cascade(
    q: Array,
    index: DTWIndex,
    cascade: CascadeConfig,
    k: int,
    exclude: Array | None,
    plan: VerificationPlan,
) -> CascadeConfig:
    """Adaptive survivor budget: only on concrete (host) inputs — under
    jit/shard_map tracing the static bucketed rule applies unchanged."""
    if (
        cascade.staged
        and cascade.adaptive_budget
        and cascade.survivor_budget is None
        and plan.compaction.budget is None
        and _all_concrete(q, index, exclude)
    ):
        budget = resolve_adaptive_budget(q, index, cascade, k, exclude)
        return dataclasses.replace(cascade, survivor_budget=budget)
    return cascade


def nn_search(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    exclude: Array | None = None,
    plan: VerificationPlan | None = None,
    with_stats: bool = False,
    with_guards: bool = False,
    sanitize: bool = False,
):
    """Exact k-NN-DTW for a batch of queries.

    Args:
      index: candidate store (build_index).
      queries: (Q, L) query batch.
      cfg: engine config; ``cfg.cascade.w`` is the DTW window.
      exclude: optional (Q,) candidate index to exclude per query
        (leave-one-out evaluation).
      plan: verification plan (tier list + compaction policy + schedule);
        ``None`` uses ``pipeline.default_plan(cfg.cascade)``.  The
        distributed path passes a plan whose compaction ``limit_fn``
        allocates the global survivor budget.  With ``cfg.auto_plan``
        this is the *base* plan the calibration prices; the committed
        optimised plan is what most blocks actually run.
      with_stats: also return a ``SearchStats`` report (host-side only —
        staged cascades on concrete inputs).  Returns ``(SearchResult,
        SearchStats)`` instead of the bare result.
      with_guards: return ``(SearchResult, GuardReport)`` instead of the
        bare result — unlike ``with_stats`` this works under tracing
        (the report is a pytree of scalars), which is how the
        distributed step surfaces guard outcomes across ``shard_map``.
        Ignored when ``with_stats`` is set (the report rides on
        ``SearchStats.guards``).
      sanitize: input hygiene for *queries* on concrete inputs: without
        it a query batch containing NaN/Inf raises; with it the bad
        values are masked to the per-series finite mean, warned about,
        and counted into the guard report (guards.validate_series).
        Store-side hygiene belongs to ``build_index``.

    Degradation (see search/guards.py): when the engine's default-on
    guards trip on concrete inputs, the batch is re-served via reference
    brute force (jnp kernels, no bound pruning — a tripped guard means
    the bounds themselves are untrusted, so any pruned rerun could
    consult the same lie), a ``GuardWarning`` fires, and the incident is
    surfaced in ``SearchStats`` (``guards`` / ``degraded``).

    Calibrate-then-commit (``cfg.auto_plan``): a cold search runs its
    first ``cfg.planner.calibrate_block`` queries under the base plan
    with stats collection, the planner turns the measurement into a
    committed plan (drop / reorder / limit-mask — search/planner.py), and
    the rest of the batch plus every later search against this store and
    config runs the committed plan.  Neighbours are bit-equal to the
    base plan's by construction; only bound work changes.
    """
    q = jnp.asarray(queries, jnp.float32)
    hyg = None
    if not isinstance(q, jax.core.Tracer):
        q, hyg = _g.validate_series(q, name="query", sanitize=sanitize)
    Q = q.shape[0]
    N = index.n
    k = min(cfg.k, N)
    cascade = cfg.cascade
    if plan is None:
        # dense engines bound every pair with the all-pairs tier list; a
        # staged default would smuggle pairwise tiers into a path that has
        # no compaction to feed them (compute_bounds rejects that loudly)
        plan = default_plan(cascade) if cascade.staged \
            else dense_plan(cascade)
    concrete = _all_concrete(q, index, exclude)
    if with_stats and not (cascade.staged and concrete):
        raise ValueError(
            "with_stats is a host-side report over the staged tier "
            "pipeline: it needs cascade.staged=True and concrete inputs"
        )

    pcfg = cfg.planner if cfg.planner is not None else PlannerConfig()
    decision = None
    stats = None
    if cfg.auto_plan and cascade.staged and concrete and Q > 0:
        decision = _planner.lookup_plan(index, cascade, k, plan, pcfg)
        if decision is not None:
            # committed: the whole batch runs the optimised plan
            res, _, guard = _search(index, q, cfg, plan=decision.plan,
                                    exclude=exclude)
            stats = decision.stats
        else:
            # calibrate: a strided query block runs the full base plan
            # (its bound pass doubles as the measurement), the rest of
            # the batch commits.  The stride keeps class-ordered batches
            # honest — a contiguous prefix can miss whole classes and
            # mis-price every tier (planner.calibration_sample).
            pick = _planner.calibration_sample(Q, pcfg.calibrate_block)
            rest = np.setdiff1d(np.arange(Q), pick)
            qa = q[pick]
            ex_a = None if exclude is None else exclude[pick]
            cascade_a = _resolve_cascade(qa, index, cascade, k, ex_a, plan)
            res_a, stats, guard = _search(index, qa, cfg, plan=plan,
                                          exclude=ex_a, cascade=cascade_a,
                                          collect_stats=True)
            decision = _planner.optimise_plan(
                plan, stats, n=N, k=k,
                base_budget=_planner.base_budget_for(
                    index, cascade_a, k, plan),
                pcfg=pcfg,
            )
            _planner.commit_plan(index, cascade, k, plan, decision, pcfg)
            if rest.size:
                ex_b = None if exclude is None else exclude[rest]
                res_b, _, guard_b = _search(index, q[rest], cfg,
                                            plan=decision.plan,
                                            exclude=ex_b)
                if guard is not None and guard_b is not None:
                    guard = guard.merge(guard_b)
                inv = jnp.asarray(np.argsort(np.concatenate([pick, rest])))
                res = SearchResult(
                    dists=jnp.concatenate([res_a.dists, res_b.dists])[inv],
                    idx=jnp.concatenate([res_a.idx, res_b.idx])[inv],
                    n_dtw=jnp.concatenate([res_a.n_dtw, res_b.n_dtw])[inv],
                    lb=jnp.concatenate([res_a.lb, res_b.lb])[inv],
                )
            else:
                res = res_a
        committed = decision.plan
    else:
        res, stats, guard = _search(index, q, cfg, plan=plan,
                                    exclude=exclude,
                                    collect_stats=with_stats)
        committed = plan

    # ---- degradation ladder layer 2 (search/guards.py) -----------------
    # a tripped admissibility / conservation / accounting / NaN-DTW guard
    # means *neither the bounds nor the compiled verification path* can
    # be trusted for this batch — pruning with a lying bound silently
    # loses neighbours, and re-running the same cascade would consult the
    # same lie.  The only sound serve is full verification: reference
    # brute force (jnp kernels, no bound pruning, no Pallas dispatch),
    # with the incident surfaced.  Host-side only — tripped() syncs.
    gcfg = _g.resolve_guards(cfg.guards)
    if hyg is not None and hyg.any() and guard is not None:
        guard = guard.merge(_g.hygiene_to_report(hyg))
    degraded = False
    if (
        guard is not None and gcfg.enabled and gcfg.degrade and concrete
        and guard.tripped()
    ):
        trip = ", ".join(guard.tripped())
        warnings.warn(
            f"exactness guards tripped ({trip}): serving this query "
            "batch via reference brute force (jnp kernels, bounds "
            "untrusted); see SearchStats.guards",
            _g.GuardWarning,
            stacklevel=2,
        )
        bf_d, bf_i = brute_force(index, q, cascade.w, k=k, exclude=exclude,
                                 use_pallas=False)
        res = SearchResult(
            dists=bf_d, idx=bf_i,
            n_dtw=jnp.full((Q,), N, jnp.int32),
            lb=res.lb,   # diagnostics only — flagged untrusted via degraded
        )
        guard = dataclasses.replace(guard, degraded=guard.degraded + 1.0)
        degraded = True

    if not with_stats:
        if with_guards:
            return res, (guard if guard is not None
                         else _g.GuardReport.zeros())
        return res
    report = SearchStats(
        tiers=stats,
        plan_tiers=tuple(t.name for t in committed.tiers),
        schedule=committed.schedule,
        dropped=decision.dropped if decision is not None else (),
        budget=decision.budget if decision is not None else None,
        limit=decision.limit if decision is not None else None,
        calibrated=decision is not None,
        n_dtw=res.n_dtw,
        n=N,
        guards=guard,
        degraded=degraded,
    )
    return res, report


def _search(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    plan: VerificationPlan,
    exclude: Array | None = None,
    cascade: CascadeConfig | None = None,
    collect_stats: bool = False,
) -> tuple[SearchResult, TierStats | None, "_g.GuardReport | None"]:
    """One engine pass under one plan (the pre-planner ``nn_search`` body).

    ``cascade`` is the budget-resolved config (``None`` resolves here);
    ``collect_stats`` threads the instrumented executor through the bound
    pass and returns its ``TierStats`` alongside the result.  The third
    return is the merged cascade + engine ``GuardReport`` (``None`` when
    guards are disabled); the degradation decision belongs to
    ``nn_search``, not here.
    """
    q = jnp.asarray(queries, jnp.float32)
    Q, L = q.shape
    N = index.n
    k = min(cfg.k, N)
    M = min(cfg.verify_chunk, N)
    if cascade is None:
        cascade = _resolve_cascade(q, index, cfg.cascade, k, exclude, plan)
    w = cascade.w
    dtw_fn = dtw_band_op if cascade.use_pallas else dtw_band_ref
    qarange = jnp.arange(Q)

    g = _g.resolve_guards(cfg.guards)
    gon = g.enabled

    tier_stats = None
    guard0 = None
    if cascade.staged:
        cres = run_plan(
            q, index, cascade, plan, k=k, dtw_fn=dtw_fn, exclude=exclude,
            collect_stats=collect_stats, guards=g,
        )
        tier_stats = cres.stats
        guard0 = cres.guard
        lb = cres.lb
        # seeds are already verified: warm-start the top-k with them and
        # drop them from the unverified ordering
        sel = jnp.argsort(cres.seed_d, axis=1)
        best_d0 = jnp.take_along_axis(cres.seed_d, sel, axis=1)
        best_i0 = jnp.take_along_axis(cres.seed_idx, sel, axis=1)
        n_dtw0 = jnp.full((Q,), k, jnp.int32)
        if gon and g.finite_gates:
            # a gated (+inf) seed was never really verified: leave its
            # bound in the ordering so the loop verifies the candidate
            # instead of losing it behind the seed mask
            cur = jnp.take_along_axis(lb, cres.seed_idx, axis=1)
            lb_order = lb.at[qarange[:, None], cres.seed_idx].set(
                jnp.where(jnp.isfinite(cres.seed_d), _INF, cur)
            )
        else:
            lb_order = lb.at[qarange[:, None], cres.seed_idx].set(_INF)
    else:
        lb = compute_bounds(q, index, cascade, k=k, plan=plan)
        best_d0 = jnp.full((Q, k), _INF, jnp.float32)
        best_i0 = jnp.full((Q, k), -1, jnp.int32)
        n_dtw0 = jnp.zeros((Q,), jnp.int32)
        lb_order = lb
    if exclude is not None:
        lb = lb.at[qarange, exclude].set(_INF)
        lb_order = lb_order.at[qarange, exclude].set(_INF)

    # ---- work-conserving flat verification scheduler -------------------
    # The naive per-query round scheme wastes whole rounds on finished
    # queries (one ambiguous straggler forces Q*M DTWs per extra round).
    # Instead each round builds a flat batch of P = Q*M (query, candidate)
    # slots striped over the *undone* queries only: every undone query
    # receives a uniform quota = min(P // n_undone, T_max) of its next
    # unverified ranks, so stragglers soak up the slots finished queries
    # no longer need (up to the static gather cap T_max = 8*M).  Total DTW
    # compute tracks the semantic verified count instead of rounds*Q*M.
    order = jnp.argsort(lb_order, axis=1)                 # (Q, N)
    slb = jnp.take_along_axis(lb_order, order, axis=1)
    slb_pad = jnp.pad(slb, ((0, 0), (0, 1)), constant_values=_INF)
    P = Q * M
    T_max = min(N, 8 * M)
    jarange = jnp.arange(P)
    max_rounds = -(-Q * N // P) + 2
    bound_sched = plan.schedule == "bound"
    # per-round pair-tile sizing: bound-ordered rounds cluster their
    # doomed tail, so a smaller tile lands the kernel's liveness exit on
    # the cluster boundary (tiling.sched_pair_tile); the plan can pin an
    # explicit size.  Unsorted rounds keep the kernel default — geometry
    # only, results and n_dtw are invariant (see pipeline.py).
    round_tile = (
        plan.verify_tile_p if plan.verify_tile_p is not None
        else sched_pair_tile(P)
    ) if bound_sched else plan.verify_tile_p

    def body(state):
        r, best_d, best_i, n_dtw, cursor, done, gacc = state
        n_un = jnp.maximum(jnp.sum(~done), 1)
        quota = jnp.minimum(P // n_un, T_max)             # ranks per query
        qorder = jnp.argsort(done)                        # undone first
        pos = jnp.argsort(qorder)                         # query -> stripe
        qi = qorder[jarange % n_un]                       # (P,) slot query
        stripe = jarange // n_un
        rank = cursor[qi] + stripe
        valid = (~done[qi]) & (rank < N) & (stripe < quota)
        rank_c = jnp.minimum(rank, N - 1)
        cidx = order[qi, rank_c]                          # candidate ids
        # exactly-+inf-sorted ranks are masked-out entries (verified
        # seeds / excluded candidates) — never re-verify them, or their
        # results would duplicate existing top-k members.  Only +inf is
        # an intentional mask: NaN or -inf there means a poisoned bound,
        # and those candidates must STAY eligible so a bad bound
        # degrades to verification (safe) instead of silent exclusion
        # (wrong answers) — guards.verification_eligible
        valid = valid & _g.verification_eligible(slb[qi, rank_c])
        lbv = jnp.where(valid, slb[qi, rank_c], _INF)
        kth0 = best_d[:, k - 1]
        # thread each query's current k-th best into the kernel's per-pair
        # early-abandon cutoff: lanes that cannot beat it return +inf
        if bound_sched:
            # bound-ordered packing: argsort the flat batch ascending by
            # its tightest bound so the loosest (most-doomed) pairs share
            # pair tiles; invalid slots sort last (+inf bound) and get a
            # -inf cutoff so they die at the first block boundary instead
            # of pinning their tile's liveness flag.  The permutation is
            # composed into the slot->row index gathers (one (P, L)
            # gather per operand, same packing the ops' ``perm=`` gather
            # would produce) and inverted on the (P,) output — everything
            # below sees the original slot order.
            perm = jnp.argsort(lbv)
            cut = jnp.where(valid, kth0[qi], -_INF)[perm]
            dp = dtw_fn(q[qi[perm]], index.series[cidx[perm]], w, cut,
                        tile_p=round_tile)
            d = unpermute_pairs(perm, dp)                 # (P,) flat
        else:
            # round_tile is None here unless the plan pinned verify_tile_p
            d = dtw_fn(q[qi], index.series[cidx], w, kth0[qi],
                       tile_p=round_tile)                 # (P,)
        z32 = jnp.zeros((), jnp.float32)
        a_chk = a_vio = a_gap = acc_chk = acc_vio = nf_dtw = z32
        if gon and g.finite_gates:
            # a NaN verification value would poison the top-k merge:
            # gate it to +inf (cannot enter the top-k) and count it —
            # nn_search's degradation decides whether +inf was safe
            d, nf_dtw = _g.finite_gate_dtw(d, valid=valid)
        d = jnp.where(valid, d, _INF)
        if gon and g.admissibility:
            # every verified lane doubles as an admissibility sample:
            # its tier bound must not exceed its exact DTW
            a_chk, a_vio, a_gap = _g.admissibility_check(
                lbv, d, g.rtol, g.atol, valid=valid
            )
        # per-query gather of this round's results (stripe layout)
        t = jnp.arange(T_max)
        slots = pos[:, None] + t[None, :] * n_un          # (Q, T_max)
        ok = (t[None, :] < quota) & (slots < P)
        slots_c = jnp.minimum(slots, P - 1)
        gd = jnp.where(ok & (qi[slots_c] == qarange[:, None]),
                       d[slots_c], _INF)
        gi = cidx[slots_c]
        alld = jnp.concatenate([best_d, gd], axis=1)
        alli = jnp.concatenate([best_i, gi], axis=1)
        neg, sel = lax.top_k(-alld, k)
        best_d = -neg
        best_i = jnp.take_along_axis(alli, sel, axis=1)
        # semantic count (the paper's pruning-power numerator): a slot is a
        # *necessary* verification if its bound still beats the post-round
        # k-th best (the sequential loop could not have skipped it) or it
        # entered the top-k.  Counting against the pre-round k-th best
        # would charge slots the sequential loop skips once the earlier
        # candidates of the same round have updated the running best.
        kth1 = best_d[:, k - 1]
        active = valid & ((lbv < kth1[qi]) | (d <= kth1[qi]))
        inc = active.astype(jnp.int32)
        seg = jax.ops.segment_sum(inc, qi, num_segments=Q)
        hook_cnt = _g.fault_hook("engine_count")
        if hook_cnt is not None:
            seg = hook_cnt(seg)
        if gon and g.accounting:
            # the per-query scatter must conserve the flat liveness
            # mirror's total — a dropped or double-counted slot here is
            # the while-loop miscompile's accounting signature
            acc_chk = jnp.asarray(1.0, jnp.float32)
            acc_vio = (jnp.sum(seg) != jnp.sum(inc)).astype(jnp.float32)
        n_dtw = n_dtw + seg
        cursor = jnp.minimum(cursor + jnp.where(~done, quota, 0), N)
        next_lb = slb_pad[qarange, cursor]
        done = done | (best_d[:, k - 1] <= next_lb) | (cursor >= N)
        if gon:
            gacc = jnp.stack([
                gacc[0] + a_chk, gacc[1] + a_vio,
                jnp.maximum(gacc[2], a_gap),
                gacc[3] + acc_chk, gacc[4] + acc_vio,
                gacc[5] + nf_dtw,
            ])
        return r + 1, best_d, best_i, n_dtw, cursor, done, gacc

    def cond(state):
        r, _, _, _, _, done, _ = state
        return (r < max_rounds) & ~jnp.all(done)

    # queries whose seeded k-th best already certifies against the smallest
    # unverified bound never enter the loop
    done0 = best_d0[:, k - 1] <= slb_pad[:, 0]
    state = (
        jnp.int32(0),
        best_d0,
        best_i0,
        n_dtw0,
        jnp.zeros((Q,), jnp.int32),
        done0,
        jnp.zeros((6,), jnp.float32),
    )
    _, best_d, best_i, n_dtw, _, _, gacc = lax.while_loop(cond, body, state)
    guard = None
    if gon:
        guard = dataclasses.replace(
            _g.GuardReport.zeros(),
            admiss_checked=gacc[0], admiss_viol=gacc[1], admiss_gap=gacc[2],
            account_checked=gacc[3], account_viol=gacc[4],
            nonfinite_dtw=gacc[5],
        )
        if g.accounting:
            # end-of-search bounds: every query verified at least its
            # seeds (staged) and never more than the whole store
            floor = k if cascade.staged else 0
            bv = jnp.sum((n_dtw > N) | (n_dtw < floor)).astype(jnp.float32)
            guard = dataclasses.replace(
                guard,
                account_checked=guard.account_checked + float(Q),
                account_viol=guard.account_viol + bv,
            )
        if guard0 is not None:
            guard = guard0.merge(guard)
    return SearchResult(dists=best_d, idx=best_i, n_dtw=n_dtw, lb=lb), \
        tier_stats, guard


def classify(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    exclude: Array | None = None,
) -> tuple[Array, SearchResult]:
    """k-NN-DTW classification: majority vote over the k neighbours."""
    res = nn_search(index, queries, cfg, exclude=exclude)
    votes = index.labels[res.idx]                                     # (Q, k)
    n_cls = int(jnp.max(index.labels)) + 1 if index.labels.size else 1
    counts = jax.vmap(
        lambda v: jnp.bincount(v, length=max(n_cls, 1))
    )(jnp.maximum(votes, 0))
    pred = jnp.argmax(counts, axis=1)
    return pred, res


def brute_force(
    index: DTWIndex, queries: Array, w: int, k: int = 1,
    *, exclude: Array | None = None, use_pallas: bool = True,
    chunk: int = 512,
) -> tuple[Array, Array]:
    """Unpruned exact k-NN (the O(N * L * W) baseline the paper speeds up).

    Chunked over candidates with a running top-k merge, so peak memory is
    O(Q * chunk * L) instead of the (Q*N, L) broadcast materialisation that
    OOMed at store scale (N=10k, L=2048).
    """
    q = jnp.asarray(queries, jnp.float32)
    Q, L = q.shape
    N = index.n
    k = min(k, N)
    chunk = min(chunk, N)
    dtw_fn = dtw_band_op if use_pallas else dtw_band_ref
    best_d = jnp.full((Q, k), _INF, jnp.float32)
    best_i = jnp.full((Q, k), -1, jnp.int32)
    for s in range(0, N, chunk):
        e = min(s + chunk, N)
        C = e - s
        qrep = jnp.repeat(q, C, axis=0)                  # (Q*C, L)
        crep = jnp.tile(index.series[s:e], (Q, 1))       # (Q*C, L)
        d = dtw_fn(qrep, crep, w).reshape(Q, C)
        ids = jnp.broadcast_to(jnp.arange(s, e, dtype=jnp.int32)[None], (Q, C))
        if exclude is not None:
            d = jnp.where(ids == exclude[:, None], _INF, d)
        alld = jnp.concatenate([best_d, d], axis=1)
        alli = jnp.concatenate([best_i, ids], axis=1)
        neg, sel = lax.top_k(-alld, k)
        best_d = -neg
        best_i = jnp.take_along_axis(alli, sel, axis=1)
    return best_d, best_i
