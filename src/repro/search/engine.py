"""Exact NN-DTW search engine with lower-bound pruning.

TPU adaptation of the paper's sequential early-abandon NN loop
(DESIGN.md SS3): instead of visiting candidates one at a time, the engine

  1. computes per-pair lower bounds by executing the verification plan's
     tier pipeline (cascade.run_plan): all-pairs tiers -> compaction ->
     pairwise tiers -> k verified seeds (or the dense full-tier matrix
     when ``cascade.staged`` is off),
  2. warm-starts the per-query top-k from the verified seeds and sorts the
     remaining candidates by ascending bound (UCR-suite ordering),
  3. verifies banded DTW in fixed-size *rounds*, threading each query's
     current k-th best distance into the kernel's per-pair ``cutoff`` so
     hopeless lanes abandon early (PrunedDTW-style), and
  4. stops a query as soon as its k-th best verified DTW is <= the smallest
     unverified bound — an *exactness certificate*: no remaining candidate
     can displace the current top-k, because bounds never exceed true DTW.

Bound-ordered verification schedule (``plan.schedule == "bound"``): each
round's flat batch of (query, candidate) slots is argsorted ascending by
its tightest bound *before* packing into the DTW kernel's pair tiles; the
engine composes the permutation into its slot->row gathers and scatters
the (P,) results back (kernels/tiling.py — external callers get the same
packing via the ops' ``perm=`` gather), so downstream accounting sees the
original slot order.  The kernel's row-block early exit skips a tile's
remaining anti-diagonal blocks only when *every* lane in the tile is
abandoned — under the unsorted stripe packing a doomed pair almost always
shares its tile with a live one, so the exit rarely fires.  Sorting
clusters the doomed pairs (loosest bounds, Herrmann & Webb's early-abandon
ordering, arXiv:2102.05221) into the same tiles, converting the per-tile
exit into an effective per-pair early exit.  The permutation changes
*packing only*: per-lane DTW values are independent of tile composition,
so results are bit-identical and per-query ``n_dtw`` (computed in slot
order from the same values) is unchanged — property-tested against the
``"index"`` schedule and brute force.

The cutoff never changes results: a lane abandons only when its frontier
minimum proves the true distance exceeds the query's current k-th best, so
the abandoned candidate could not have entered the top-k anyway.

The result is exact (identical neighbours to brute force — property-tested)
and the number of verified candidates matches what the paper's pruning-power
metric counts: ``P = 1 - n_dtw / N``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ops import dtw_band_op
from repro.kernels.ref import dtw_band_ref
from repro.kernels.tiling import sched_pair_tile, unpermute_pairs
from repro.search.cascade import (
    CascadeConfig,
    compute_bounds,
    run_plan,
)
from repro.search.index import DTWIndex
from repro.search.pipeline import (
    VerificationPlan,
    default_plan,
    dense_plan,
    resolve_adaptive_budget,
)

Array = jax.Array

_INF = jnp.inf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Exact k-NN under DTW_w plus pruning accounting.

    Attributes:
      dists: (Q, k) squared-cost DTW distances, ascending.
      idx:   (Q, k) candidate indices into the store.
      n_dtw: (Q,) number of DTW verifications actually performed.
      lb:    (Q, N) the cascade bound matrix (for diagnostics/benchmarks).
    """

    dists: Array
    idx: Array
    n_dtw: Array
    lb: Array

    def pruning_power(self, n: int | None = None) -> Array:
        n = n if n is not None else self.lb.shape[1]
        return 1.0 - self.n_dtw / n


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs on top of the cascade config.

    Attributes:
      cascade: the lower-bound cascade configuration.
      verify_chunk: DTW verifications per round (the TPU batch analogue of
        the paper's one-at-a-time loop; each round is one fused kernel
        launch of ``Q * verify_chunk`` banded-DTW lane problems).
      k: neighbours to return.
    """

    cascade: CascadeConfig
    verify_chunk: int = 32
    k: int = 1


def nn_search(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    exclude: Array | None = None,
    plan: VerificationPlan | None = None,
) -> SearchResult:
    """Exact k-NN-DTW for a batch of queries.

    Args:
      index: candidate store (build_index).
      queries: (Q, L) query batch.
      cfg: engine config; ``cfg.cascade.w`` is the DTW window.
      exclude: optional (Q,) candidate index to exclude per query
        (leave-one-out evaluation).
      plan: verification plan (tier list + compaction policy + schedule);
        ``None`` uses ``pipeline.default_plan(cfg.cascade)``.  The
        distributed path passes a plan whose compaction ``limit_fn``
        allocates the global survivor budget.
    """
    q = jnp.asarray(queries, jnp.float32)
    Q, L = q.shape
    N = index.n
    k = min(cfg.k, N)
    M = min(cfg.verify_chunk, N)
    cascade = cfg.cascade
    w = cascade.w
    dtw_fn = dtw_band_op if cascade.use_pallas else dtw_band_ref
    qarange = jnp.arange(Q)
    if plan is None:
        # dense engines bound every pair with the all-pairs tier list; a
        # staged default would smuggle pairwise tiers into a path that has
        # no compaction to feed them (compute_bounds rejects that loudly)
        plan = default_plan(cascade) if cascade.staged \
            else dense_plan(cascade)

    # adaptive survivor budget: only on concrete (host) inputs — under
    # jit/shard_map tracing the static bucketed rule applies unchanged
    if (
        cascade.staged
        and cascade.adaptive_budget
        and cascade.survivor_budget is None
        and plan.compaction.budget is None
        and not isinstance(q, jax.core.Tracer)
        and not isinstance(index.series, jax.core.Tracer)
        and not isinstance(exclude, jax.core.Tracer)
    ):
        budget = resolve_adaptive_budget(q, index, cascade, k, exclude)
        cascade = dataclasses.replace(cascade, survivor_budget=budget)

    if cascade.staged:
        cres = run_plan(
            q, index, cascade, plan, k=k, dtw_fn=dtw_fn, exclude=exclude
        )
        lb = cres.lb
        # seeds are already verified: warm-start the top-k with them and
        # drop them from the unverified ordering
        sel = jnp.argsort(cres.seed_d, axis=1)
        best_d0 = jnp.take_along_axis(cres.seed_d, sel, axis=1)
        best_i0 = jnp.take_along_axis(cres.seed_idx, sel, axis=1)
        n_dtw0 = jnp.full((Q,), k, jnp.int32)
        lb_order = lb.at[qarange[:, None], cres.seed_idx].set(_INF)
    else:
        lb = compute_bounds(q, index, cascade, k=k, plan=plan)
        best_d0 = jnp.full((Q, k), _INF, jnp.float32)
        best_i0 = jnp.full((Q, k), -1, jnp.int32)
        n_dtw0 = jnp.zeros((Q,), jnp.int32)
        lb_order = lb
    if exclude is not None:
        lb = lb.at[qarange, exclude].set(_INF)
        lb_order = lb_order.at[qarange, exclude].set(_INF)

    # ---- work-conserving flat verification scheduler -------------------
    # The naive per-query round scheme wastes whole rounds on finished
    # queries (one ambiguous straggler forces Q*M DTWs per extra round).
    # Instead each round builds a flat batch of P = Q*M (query, candidate)
    # slots striped over the *undone* queries only: every undone query
    # receives a uniform quota = min(P // n_undone, T_max) of its next
    # unverified ranks, so stragglers soak up the slots finished queries
    # no longer need (up to the static gather cap T_max = 8*M).  Total DTW
    # compute tracks the semantic verified count instead of rounds*Q*M.
    order = jnp.argsort(lb_order, axis=1)                 # (Q, N)
    slb = jnp.take_along_axis(lb_order, order, axis=1)
    slb_pad = jnp.pad(slb, ((0, 0), (0, 1)), constant_values=_INF)
    P = Q * M
    T_max = min(N, 8 * M)
    jarange = jnp.arange(P)
    max_rounds = -(-Q * N // P) + 2
    bound_sched = plan.schedule == "bound"
    # per-round pair-tile sizing: bound-ordered rounds cluster their
    # doomed tail, so a smaller tile lands the kernel's liveness exit on
    # the cluster boundary (tiling.sched_pair_tile); the plan can pin an
    # explicit size.  Unsorted rounds keep the kernel default — geometry
    # only, results and n_dtw are invariant (see pipeline.py).
    round_tile = (
        plan.verify_tile_p if plan.verify_tile_p is not None
        else sched_pair_tile(P)
    ) if bound_sched else plan.verify_tile_p

    def body(state):
        r, best_d, best_i, n_dtw, cursor, done = state
        n_un = jnp.maximum(jnp.sum(~done), 1)
        quota = jnp.minimum(P // n_un, T_max)             # ranks per query
        qorder = jnp.argsort(done)                        # undone first
        pos = jnp.argsort(qorder)                         # query -> stripe
        qi = qorder[jarange % n_un]                       # (P,) slot query
        stripe = jarange // n_un
        rank = cursor[qi] + stripe
        valid = (~done[qi]) & (rank < N) & (stripe < quota)
        rank_c = jnp.minimum(rank, N - 1)
        cidx = order[qi, rank_c]                          # candidate ids
        # +inf-sorted ranks are masked-out entries (verified seeds /
        # excluded candidates) — never re-verify them, or their results
        # would duplicate existing top-k members
        valid = valid & jnp.isfinite(slb[qi, rank_c])
        lbv = jnp.where(valid, slb[qi, rank_c], _INF)
        kth0 = best_d[:, k - 1]
        # thread each query's current k-th best into the kernel's per-pair
        # early-abandon cutoff: lanes that cannot beat it return +inf
        if bound_sched:
            # bound-ordered packing: argsort the flat batch ascending by
            # its tightest bound so the loosest (most-doomed) pairs share
            # pair tiles; invalid slots sort last (+inf bound) and get a
            # -inf cutoff so they die at the first block boundary instead
            # of pinning their tile's liveness flag.  The permutation is
            # composed into the slot->row index gathers (one (P, L)
            # gather per operand, same packing the ops' ``perm=`` gather
            # would produce) and inverted on the (P,) output — everything
            # below sees the original slot order.
            perm = jnp.argsort(lbv)
            cut = jnp.where(valid, kth0[qi], -_INF)[perm]
            dp = dtw_fn(q[qi[perm]], index.series[cidx[perm]], w, cut,
                        tile_p=round_tile)
            d = unpermute_pairs(perm, dp)                 # (P,) flat
        else:
            # round_tile is None here unless the plan pinned verify_tile_p
            d = dtw_fn(q[qi], index.series[cidx], w, kth0[qi],
                       tile_p=round_tile)                 # (P,)
        d = jnp.where(valid, d, _INF)
        # per-query gather of this round's results (stripe layout)
        t = jnp.arange(T_max)
        slots = pos[:, None] + t[None, :] * n_un          # (Q, T_max)
        ok = (t[None, :] < quota) & (slots < P)
        slots_c = jnp.minimum(slots, P - 1)
        gd = jnp.where(ok & (qi[slots_c] == qarange[:, None]),
                       d[slots_c], _INF)
        gi = cidx[slots_c]
        alld = jnp.concatenate([best_d, gd], axis=1)
        alli = jnp.concatenate([best_i, gi], axis=1)
        neg, sel = lax.top_k(-alld, k)
        best_d = -neg
        best_i = jnp.take_along_axis(alli, sel, axis=1)
        # semantic count (the paper's pruning-power numerator): a slot is a
        # *necessary* verification if its bound still beats the post-round
        # k-th best (the sequential loop could not have skipped it) or it
        # entered the top-k.  Counting against the pre-round k-th best
        # would charge slots the sequential loop skips once the earlier
        # candidates of the same round have updated the running best.
        kth1 = best_d[:, k - 1]
        active = valid & ((lbv < kth1[qi]) | (d <= kth1[qi]))
        n_dtw = n_dtw + jax.ops.segment_sum(
            active.astype(jnp.int32), qi, num_segments=Q
        )
        cursor = jnp.minimum(cursor + jnp.where(~done, quota, 0), N)
        next_lb = slb_pad[qarange, cursor]
        done = done | (best_d[:, k - 1] <= next_lb) | (cursor >= N)
        return r + 1, best_d, best_i, n_dtw, cursor, done

    def cond(state):
        r, _, _, _, _, done = state
        return (r < max_rounds) & ~jnp.all(done)

    # queries whose seeded k-th best already certifies against the smallest
    # unverified bound never enter the loop
    done0 = best_d0[:, k - 1] <= slb_pad[:, 0]
    state = (
        jnp.int32(0),
        best_d0,
        best_i0,
        n_dtw0,
        jnp.zeros((Q,), jnp.int32),
        done0,
    )
    _, best_d, best_i, n_dtw, _, _ = lax.while_loop(cond, body, state)
    return SearchResult(dists=best_d, idx=best_i, n_dtw=n_dtw, lb=lb)


def classify(
    index: DTWIndex,
    queries: Array,
    cfg: EngineConfig,
    *,
    exclude: Array | None = None,
) -> tuple[Array, SearchResult]:
    """k-NN-DTW classification: majority vote over the k neighbours."""
    res = nn_search(index, queries, cfg, exclude=exclude)
    votes = index.labels[res.idx]                                     # (Q, k)
    n_cls = int(jnp.max(index.labels)) + 1 if index.labels.size else 1
    counts = jax.vmap(
        lambda v: jnp.bincount(v, length=max(n_cls, 1))
    )(jnp.maximum(votes, 0))
    pred = jnp.argmax(counts, axis=1)
    return pred, res


def brute_force(
    index: DTWIndex, queries: Array, w: int, k: int = 1,
    *, exclude: Array | None = None, use_pallas: bool = True,
    chunk: int = 512,
) -> tuple[Array, Array]:
    """Unpruned exact k-NN (the O(N * L * W) baseline the paper speeds up).

    Chunked over candidates with a running top-k merge, so peak memory is
    O(Q * chunk * L) instead of the (Q*N, L) broadcast materialisation that
    OOMed at store scale (N=10k, L=2048).
    """
    q = jnp.asarray(queries, jnp.float32)
    Q, L = q.shape
    N = index.n
    k = min(k, N)
    chunk = min(chunk, N)
    dtw_fn = dtw_band_op if use_pallas else dtw_band_ref
    best_d = jnp.full((Q, k), _INF, jnp.float32)
    best_i = jnp.full((Q, k), -1, jnp.int32)
    for s in range(0, N, chunk):
        e = min(s + chunk, N)
        C = e - s
        qrep = jnp.repeat(q, C, axis=0)                  # (Q*C, L)
        crep = jnp.tile(index.series[s:e], (Q, 1))       # (Q*C, L)
        d = dtw_fn(qrep, crep, w).reshape(Q, C)
        ids = jnp.broadcast_to(jnp.arange(s, e, dtype=jnp.int32)[None], (Q, C))
        if exclude is not None:
            d = jnp.where(ids == exclude[:, None], _INF, d)
        alld = jnp.concatenate([best_d, d], axis=1)
        alli = jnp.concatenate([best_i, ids], axis=1)
        neg, sel = lax.top_k(-alld, k)
        best_d = -neg
        best_i = jnp.take_along_axis(alli, sel, axis=1)
    return best_d, best_i
