"""Self-tuning tier planner: measured mass/cost plan optimisation.

The paper's central trade-off — every lower bound buys pruning mass at a
compute cost, and the right mix shifts with the window and the data — was
hand-tuned until now: ``VerificationPlan`` tiers are declarative but
statically ordered, so a plan that pays at ``w = 0.1 L`` wastes a full
pairwise pass at ``w = L`` where the bands-tier mass collapses.  This
module closes the loop, executing Herrmann & Webb's "order bounds by
expected value" argument (arXiv:2102.05221) at *plan* level and Lemire's
two-pass gating (arXiv:0811.3301) as a measured decision instead of a
convention:

  1. **measure** — the instrumented executor
     (``cascade.run_plan(collect_stats=True)``) prices every tier of a
     plan on real queries: incremental realised pruning mass at the
     seed-verified threshold ``tau``, pairs scored, and cost-class-
     weighted work (``pipeline.TierStats``);
  2. **decide** — ``optimise_plan`` rewrites the plan from the
     measurement: tiers whose realised mass is a negligible fraction of
     the measured pairs are **dropped** (dropping only loosens bounds, so
     exactness is inherited from the running-max argument); surviving
     tiers are **reordered** by mass-per-work (running max is
     commutative, so this is attribution/future-gating order, never
     semantics); and the compaction is **limit-masked** — the budget
     shrinks to a bucketed cap of the measured per-query survivor mass
     and a constant refine limit masks the residual slots, which the
     per-slot liveness kernels (PR 4) turn into genuinely skipped work;
  3. **commit** — the decision is cached per (store identity, window, k,
     config, base-plan shape), so ``engine.nn_search``'s calibrate-then-
     commit flow pays measurement once and every later block (or a whole
     serving process, via ``build_index(calibrate=...)``) runs the
     optimised plan.

Every decision is *bucketed* like the adaptive survivor budget — budgets
are power-of-two buckets, refine limits are sublane (8) multiples — so
the committed plan is static data and the executor stays jit/shard_map-
traceable with O(log N) distinct shapes.

Exactness: a planner-emitted plan can only *remove* bound work — drop a
tier, skip refinement of packed slots whose cheap bound already exceeds
``tau`` — and unrefined pairs keep a valid (looser) lower bound, so the
engine's verified neighbours are bit-equal to the default plan's by the
same argument that makes any plan exact.  The limit cap is chosen with
headroom over the measured survivor mass (``limit_safety``, then bucket
rounding), so on the calibration distribution the masked slots are
exactly the pairs the engine could never verify — measured, not assumed
(property-tested in tests/test_planner.py across windows and skewed
stores).

The distributed path reuses this machinery unchanged: each shard runs the
instrumented executor locally, ``TierStats`` is a pytree so the shard
measurements are ``psum``-merged over the mesh axes (the same gather
pattern as ``global_budget_limit_fn``), and every shard derives the same
decision from the same global stats — one committed plan for the fleet
(search/distributed.py).
"""

from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tiling import round_up
from repro.search.pipeline import (
    Compaction,
    TierStats,
    VerificationPlan,
    bucket_pow2,
    default_plan,
)

Array = jax.Array

# planner buckets: budgets snap to powers of two (pipeline.bucket_pow2,
# the cascade's rule at floor 8 — the planner only ever *shrinks* the
# cascade's 64-floor buckets, and the pair-tile sublane floor is 8),
# refine limits to sublane multiples of 8.  Bounded decision vocabulary
# = bounded recompilation, same argument as the adaptive budget's rule.
_BUCKET_FLOOR = 8


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Decision thresholds for ``optimise_plan``.

    Attributes:
      drop_mass_frac: drop a tier whose incremental realised pruning mass
        is <= this fraction of the measured pairs.  The default ``0.0``
        is the *conservative* profile: only measured-idle tiers — zero
        crossings on the calibration block — are removed.  The
        measurement is taken at the seed threshold ``tau``, so this is a
        strong empirical signal, not a proof: a zero-mass tier can in
        principle still order below-``tau`` bounds that the engine's
        stopping rule reads, so the "committed n_dtw never exceeds the
        base plan's" property is what the calibration-representative
        workloads in tests/test_planner.py pin, not a theorem (an
        all-zero measurement is additionally rejected outright — see
        ``optimise_plan``).  A positive value is the *expected-value*
        profile (Herrmann & Webb's ordering argument taken to its
        conclusion): a tier whose mass is a negligible fraction of the
        measured pairs is dropped even though it pruned a little,
        trading a bounded handful of extra DTW verifications for the
        whole tier's cost class — exactness is untouched either way.
      limit_safety: headroom multiplier on the measured per-query survivor
        mass before bucketing the refine limit/budget (the power-of-two
        bucket rounding then adds 0-100% more, so the committed width
        carries at least ~30% slack over the measured maximum —
        ``choose_survivor_budget``'s safety philosophy at plan level).
      limit_slack: only attach a refine-limit mask when the capped limit
        is <= this fraction of the committed budget — masking a sliver of
        the packed width is bookkeeping, not savings.
      reorder: reorder surviving tiers by measured mass-per-work
        (descending).  Running max is commutative, so this is measurement
        attribution and future gating order only.
      calibrate_block: queries in the engine's calibration block (the
        first block of a cold ``nn_search`` runs the base plan to populate
        stats; the rest of the batch commits).
    """

    drop_mass_frac: float = 0.0
    limit_safety: float = 1.3
    limit_slack: float = 0.75
    reorder: bool = True
    calibrate_block: int = 8


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """One committed plan rewrite plus the measurement that justified it.

    Attributes:
      plan: the validated optimised ``VerificationPlan`` to commit.
      base: the plan the measurement priced.
      stats: the host-side ``TierStats`` the decision was derived from.
      dropped: names of tiers removed from the base plan.
      order: committed tier names, in committed order.
      budget: committed compaction budget bucket (``None`` = base left
        untouched).
      limit: committed constant refine limit (``None`` = no mask).
    """

    plan: VerificationPlan
    base: VerificationPlan
    stats: TierStats
    dropped: tuple[str, ...]
    order: tuple[str, ...]
    budget: int | None
    limit: int | None

    def summary(self) -> str:
        parts = [" -> ".join(self.order) if self.order else "<no tiers>"]
        if self.dropped:
            parts.append(f"dropped: {', '.join(self.dropped)}")
        if self.budget is not None:
            parts.append(f"budget={self.budget}")
        if self.limit is not None:
            parts.append(f"limit={self.limit}")
        return "   ".join(parts)


def _host_stats(stats: TierStats) -> TierStats:
    """Sync a (possibly traced-then-computed) TierStats to host numpy."""
    return dataclasses.replace(
        stats,
        mass=np.asarray(stats.mass, dtype=np.float64),
        scored=np.asarray(stats.scored, dtype=np.float64),
        work=np.asarray(stats.work, dtype=np.float64),
        pairs=float(np.asarray(stats.pairs)),
        queries=float(np.asarray(stats.queries)),
        survivors=np.asarray(stats.survivors, dtype=np.float64),
    )


def optimise_plan(
    base: VerificationPlan,
    stats: TierStats,
    *,
    n: int,
    k: int,
    base_budget: int,
    pcfg: PlannerConfig | None = None,
) -> PlanDecision:
    """Rewrite ``base`` from its measured ``TierStats`` (module docstring).

    ``n`` is the (per-shard) store size the committed budget is clamped
    to; ``base_budget`` is the packed width the base plan would have used
    (explicit compaction budget, adaptive bucket, or the static rule) —
    the planner only ever shrinks it.  Returns a ``PlanDecision`` whose
    plan is validated by construction (``VerificationPlan.__post_init__``
    runs on it).
    """
    pcfg = pcfg if pcfg is not None else PlannerConfig()
    st = _host_stats(stats)
    names = tuple(t.name for t in base.tiers)
    if len(set(names)) != len(names):
        # the executor tolerates duplicate names (it runs fns, not
        # names), but every planner decision — attribution, drops, the
        # commit-cache signature — is keyed by name, so a duplicate
        # would silently rewrite the wrong tier
        raise ValueError(
            f"duplicate tier names in plan {names!r}: the planner keys "
            "decisions by name; give each tier a distinct one"
        )
    by_name = {t.name: t for t in base.tiers}
    if tuple(st.names) != names:
        raise ValueError(
            f"stats tiers {st.names!r} do not match plan tiers "
            f"{names!r}; price the plan you are optimising"
        )

    pairs = max(st.pairs, 1.0)
    ratio = st.mass_per_work()
    if not np.any(np.asarray(st.mass) > 0):
        # Degenerate measurement: no tier crossed the threshold anywhere.
        # Either the bounds are genuinely useless on this workload (w = L
        # on incompressible data) or the threshold itself collapsed
        # (tau = 0 — e.g. a store with duplicate series under LOO
        # calibration, where every sampled query's twin verifies at
        # distance zero and ``prev < tau`` can never fire).  A zero
        # measurement cannot distinguish the two, and acting on it would
        # drop *every* tier and shrink the budget to the floor — so the
        # only safe commit is the base plan unchanged.
        return PlanDecision(
            plan=base, base=base, stats=st, dropped=(),
            order=tuple(t.name for t in base.tiers),
            budget=None, limit=None,
        )
    keep, dropped = [], []
    for i, name in enumerate(st.names):
        if st.mass[i] <= pcfg.drop_mass_frac * pairs:
            dropped.append(name)
        else:
            keep.append((i, name))
    # a surviving pairwise tier needs a surviving all_pairs tier: the
    # compaction selects survivors by the all-pairs running max, and an
    # all-zero selection key would pack an arbitrary, query-independent
    # candidate set — keep the best-measured cheap tier as the key even
    # when its own crossings were zero
    if (
        any(st.scopes[i] == "pairwise" for i, _ in keep)
        and not any(st.scopes[i] == "all_pairs" for i, _ in keep)
    ):
        ap = [i for i, s in enumerate(st.scopes) if s == "all_pairs"]
        if ap:
            best = max(ap, key=lambda i: (st.mass[i], ratio[i], -i))
            keep.append((best, st.names[best]))
            dropped.remove(st.names[best])
    if pcfg.reorder:
        # Herrmann & Webb's expected-value order at plan level: highest
        # measured mass-per-work first, within each scope (the single
        # compaction point keeps all_pairs tiers ahead of pairwise ones)
        keep.sort(key=lambda it: (st.scopes[it[0]] == "pairwise",
                                  -ratio[it[0]], it[0]))
    else:
        keep.sort(key=lambda it: it[0])     # base plan order stays valid
    tiers = tuple(by_name[name] for _, name in keep)

    comp = base.compaction
    budget = limit = None
    if any(t.scope == "pairwise" for t in tiers):
        smax = float(np.max(st.survivors)) if np.size(st.survivors) else 0.0
        cap = max(int(math.ceil(smax * pcfg.limit_safety)), 4 * k,
                  _BUCKET_FLOOR)
        budget = min(base_budget, bucket_pow2(cap, _BUCKET_FLOOR), n)
        limit_c = min(round_up(cap, 8), budget)   # sublane-rounded limit
        new_comp = dataclasses.replace(comp, budget=budget)
        if comp.limit_fn is not None:
            # compose with the existing policy (the distributed global
            # budget): both only shrink refinement, min is still valid
            prev_fn = comp.limit_fn
            new_comp = dataclasses.replace(
                new_comp,
                limit_fn=_compose_limit(prev_fn, limit_c),
            )
            limit = limit_c
        elif limit_c <= pcfg.limit_slack * budget:
            new_comp = dataclasses.replace(
                new_comp, limit_fn=_const_limit(limit_c), width_scale=1
            )
            limit = limit_c
        comp = new_comp
    plan = dataclasses.replace(base, tiers=tiers, compaction=comp)
    return PlanDecision(
        plan=plan,
        base=base,
        stats=st,
        dropped=tuple(dropped),
        order=tuple(t.name for t in tiers),
        budget=budget,
        limit=limit,
    )


def calibration_sample(n: int, sample: int) -> np.ndarray:
    """Strided host-side calibration indices (sorted, unique).

    A *contiguous* first block is an adversarial sample on class-ordered
    data (the UCR convention): the measured mass and survivor counts then
    describe only the leading classes, and the committed plan under-
    covers the rest.  A stride across the full range puts every region of
    the batch/store in the measurement for the same sample size.
    """
    s = max(1, min(sample, n))
    return np.unique(np.round(np.linspace(0, n - 1, s)).astype(np.int64))


def _const_limit(limit: int) -> Callable:
    def limit_fn(lb01, budget, k):
        return jnp.full((lb01.shape[0],), limit, jnp.int32)

    return limit_fn


def _compose_limit(prev_fn: Callable, limit: int) -> Callable:
    def limit_fn(lb01, budget, k):
        return jnp.minimum(
            prev_fn(lb01, budget, k), jnp.int32(limit)
        ).astype(jnp.int32)

    return limit_fn


# ---------------------------------------------------------------------------
# commit cache: one measured decision per (store, window, k, config, plan)
# ---------------------------------------------------------------------------

# Mirrors pipeline's adaptive-budget memo: entries hold a weakref to the
# store's series array and hit only while that exact array is alive.  The
# key deliberately has no leave-one-out flag — a plan calibrated with LOO
# exclusion is *conservative* for plain serving (excluding the self-match
# raises tau, which raises the measured survivor mass and the committed
# limit), so build-time LOO calibration warms ordinary queries too.
_PLAN_CACHE: dict = {}
_PLAN_CACHE_MAX = 64


def _plan_sig(plan: VerificationPlan) -> tuple:
    comp = plan.compaction
    return (
        tuple(t.name for t in plan.tiers),
        plan.schedule,
        plan.verify_tile_p,
        comp.budget,
        comp.width_scale,
        # the callback object itself (hashed by identity): two plans
        # differing only in their limit policy are different decisions,
        # and the strong reference in the key prevents id reuse
        comp.limit_fn,
    )


def _plan_cache_key(index, cascade, k: int, base: VerificationPlan,
                    pcfg: PlannerConfig | None) -> tuple:
    pcfg = pcfg if pcfg is not None else PlannerConfig()
    return (
        id(index.series),
        index.n,
        cascade.w,
        k,
        cascade.v,
        cascade.use_kim,
        getattr(cascade, "use_sketch", False),
        cascade.use_pallas,
        cascade.survivor_budget,
        # sketch-feature and store-mask presence change what the same
        # tier list measures (the sketch tier is zeros without features;
        # masked tiers score fewer pairs), so they are part of the
        # decision's identity even though the tier names match
        getattr(index, "sk_lo", None) is not None,
        getattr(index, "live", None) is not None,
        _plan_sig(base),
        dataclasses.astuple(pcfg),    # thresholds change the decision
    )


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()


def plan_cache_len() -> int:
    return len(_PLAN_CACHE)


def lookup_plan(index, cascade, k: int, base: VerificationPlan,
                pcfg: PlannerConfig | None = None) -> PlanDecision | None:
    """Committed decision for this (store, config, base plan, planner
    thresholds), if alive."""
    hit = _PLAN_CACHE.get(_plan_cache_key(index, cascade, k, base, pcfg))
    if hit is not None and hit[0]() is index.series:
        return hit[1]
    return None


def commit_plan(index, cascade, k: int, base: VerificationPlan,
                decision: PlanDecision,
                pcfg: PlannerConfig | None = None) -> PlanDecision:
    """Cache a decision so later searches start from the committed plan."""
    if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
        _PLAN_CACHE.clear()
    key = _plan_cache_key(index, cascade, k, base, pcfg)
    _PLAN_CACHE[key] = (weakref.ref(index.series), decision)
    return decision


def base_budget_for(index, cascade, k: int, base: VerificationPlan) -> int:
    """The packed width the base plan would refine — what the planner is
    allowed to shrink."""
    if base.compaction.budget is not None:
        return max(1, min(index.n, base.compaction.budget))
    return cascade.budget(index.n, k)


def calibrate_plan(
    q: Array,
    index,
    cascade,
    k: int = 1,
    *,
    plan: VerificationPlan | None = None,
    exclude: Array | None = None,
    sample: int = 8,
    pcfg: PlannerConfig | None = None,
) -> PlanDecision:
    """Measure-decide-commit in one host-side call.

    Runs the instrumented executor on a ``sample``-query block, prices the
    (given or default) base plan, and commits the optimised plan for this
    (store, config) — the standalone entry the index build-time
    calibration and the benches use; ``engine.nn_search`` reaches the same
    commit through its first-block search instead, so serving pays no
    extra bound pass.  Concrete (host) inputs only, like
    ``choose_survivor_budget``.
    """
    from repro.search.cascade import run_plan
    from repro.search.pipeline import resolve_adaptive_budget

    base = plan if plan is not None else default_plan(cascade)
    q = jnp.asarray(q, jnp.float32)
    pick = calibration_sample(q.shape[0], sample)
    qs = q[pick]
    ex = None if exclude is None else jnp.asarray(exclude)[pick]
    cascade_r = cascade
    if (
        cascade.adaptive_budget
        and cascade.survivor_budget is None
        and base.compaction.budget is None
    ):
        budget = resolve_adaptive_budget(qs, index, cascade, k, ex)
        cascade_r = dataclasses.replace(cascade, survivor_budget=budget)
    cres = run_plan(qs, index, cascade_r, base, k=k, exclude=ex,
                    collect_stats=True)
    if cres.guard is not None and cres.guard.tripped():
        # a measurement taken under a tripped exactness guard prices
        # garbage — committing a rewrite from it would pin a poisoned
        # plan on every later search against this store.  Commit the
        # base plan unchanged instead (same no-rewrite shape as the
        # degenerate all-zero-mass measurement) and let the runtime
        # guards/degradation handle the searches themselves.
        import warnings as _warnings

        from repro.search.guards import GuardWarning

        _warnings.warn(
            "plan calibration measured under tripped exactness guards "
            f"({', '.join(cres.guard.tripped())}); committing the base "
            "plan unchanged",
            GuardWarning,
            stacklevel=2,
        )
        decision = PlanDecision(
            plan=base, base=base, stats=_host_stats(cres.stats),
            dropped=(), order=tuple(t.name for t in base.tiers),
            budget=None, limit=None,
        )
    else:
        decision = optimise_plan(
            base, cres.stats, n=index.n, k=k,
            base_budget=base_budget_for(index, cascade_r, k, base),
            pcfg=pcfg,
        )
    return commit_plan(index, cascade, k, base, decision, pcfg)
