"""Runtime exactness guards: invariant checking, containment, degradation.

DESIGN — why this module exists
-------------------------------

The whole search stack sells one contract: results bit-equal to brute
force.  PRs 1-5 *prove* that contract on clean inputs and a correct
compiler — but the carried jax 0.4.x ``jit(shard_map(while))`` miscompile
shows the contract can fail *silently* (candidates dropped with no
error), and nothing validated inputs: a single NaN in a stored series
poisons envelopes, Kim features, and every admissible bound without any
signal (a NaN bound compares ``False`` everywhere, so the cascade simply
stops pruning — or worse, a +inf bound excludes a true neighbour).  This
module adds the three layers that make wrong-answer and poison-input
failure modes *detectable*, *contained*, and *recoverable*.

DESIGN — guard taxonomy
-----------------------

Every guard is a cheap, jit-compatible invariant check that **counts
violations into a ``GuardReport`` instead of raising** (raising is
impossible under trace; a count is psum-mergeable across shards like
``TierStats``):

  * **admissibility** (``admissibility_check``): sampled (bound, verified
    DTW) pairs must satisfy ``LB <= DTW`` within float tolerance — the
    paper's admissibility argument (and Lemire arXiv:0811.3301) is the
    exactness foundation, so a single violation means a tier, a kernel,
    or the data is lying.  Sampling is free: the cascade's seed
    verification and every engine round already compute exact DTW for
    the tightest-bound pairs, so the guard only compares numbers that
    were going to exist anyway.
  * **conservation** (``conservation_check`` + the scatter-monotonicity
    check in ``cascade.run_plan``): gather-compaction must select exactly
    ``W`` *distinct* candidates per query, and the scatter-max back into
    the bound matrix can only tighten (``lb_after >= lb_before``
    everywhere).  This is the guard that catches the shard_map
    miscompile *shape* — a live candidate silently dropped by a
    gather/pack — at the pipeline stage where it would happen.
  * **accounting** (engine): the engine's counted verifications
    (``n_dtw`` via ``segment_sum``) must match an independent total each
    round, and ``k <= n_dtw <= N`` must hold at the end.  A while-loop
    miscompile that drops rounds or double-counts shows up here.
  * **finite gates** (``finite_gate_bounds``): tier outputs must be
    finite or ``-inf`` (the legitimate dead-slot identity).  NaN / +inf
    tier values are *gated to -inf* — a trivially valid lower bound, so
    a poisoned bound degrades to "verify this candidate" (safe) instead
    of "never verify it" (wrong answers).  NaN DTW outputs in the engine
    are gated to +inf and counted; +inf there means "treat as
    unverifiable", which the host-side degradation ladder then repairs.

DESIGN — trace-compatibility rules
----------------------------------

  1. Guards never raise under trace: every check folds into float32
     counters carried in ``GuardReport`` (a registered pytree).
  2. Guard arithmetic is pure jnp (elementwise compares + reductions),
     so guarded executors still trace under ``jit`` / ``shard_map`` and
     reports ``psum``-merge across mesh axes
     (``GuardReport.to_vector`` crosses shard_map boundaries as a plain
     ``(G,)`` array).
  3. Host-only decisions (degradation reruns, preflight, input hygiene)
     run only on concrete inputs — under tracing they silently defer,
     the same contract as the adaptive budget and the planner.
  4. On clean finite data every gate is the identity, so guarded and
     unguarded runs are bit-equal (property-tested); guards change
     *work* by a priced, CI-bounded amount (``guard_overhead_*`` bench
     rows, <= 5% on the bound pass), never results.

DESIGN — degradation ladder
---------------------------

  0. **preflight** — before serving traffic, prove the compiled path on
     a canary: ``preflight_engine()`` (single-device jitted engine vs
     brute force) and ``preflight_shard_map(mesh, ...)`` (the exact
     ``jit(shard_map(while))`` shape that miscompiles on jax 0.4.x,
     compared against host brute force).  ``make_distributed_search``
     runs the latter by default and auto-selects the safe unjitted path
     with a one-per-process warning — the detection that replaced the
     docs-only workaround.
  1. **in-trace containment** — finite gates replace poisoned bounds
     with -inf (degrade to verification) and poisoned DTW values with
     +inf, and count every gated value.  Exactness is preserved whenever
     the *verification* values are sound; the counts say when they were
     not.
  2. **host-side rerun** — on a tripped admissibility / conservation /
     accounting / NaN-DTW guard, ``nn_search`` re-serves the affected
     query block via reference brute force (``kernels/ref.py`` jnp
     mirrors, *no bound pruning* — a tripped guard means the bounds are
     untrusted, and a pruned rerun would consult the same lie), marks
     the result ``degraded``, and surfaces the incident in
     ``SearchStats``.
  3. **input hygiene** — ``validate_series`` at ``build_index`` /
     ``nn_search`` rejects (or, with ``sanitize=True``, masks and
     reports) NaN/Inf values and zero-variance series *before* z-norm,
     so layer 1 and 2 never fire on garbage the boundary could have
     refused.

Fault-injection seams
---------------------

``testing/faults.py`` proves every guard *trips*, not just that clean
runs pass.  The injectors install hooks into the ``_FAULT_HOOKS``
registry below; production call sites consult it with a single dict
lookup that is ``None`` outside the harness (zero cost, no behaviour).
The seams are: ``compaction_cand`` (corrupt the gather-compaction's
selected candidates — the miscompile replay), ``packed_rows`` (NaN/Inf
corruption of the packed survivor tiles), ``tier_out`` (corrupt a bound
tier's output), ``dtw_out`` (corrupt the DTW kernel dispatch's results,
kernels/ops.py), ``engine_count`` (perturb the engine's round
accounting), ``allgather_topk`` (simulated shard dropout in the
distributed top-k merge), and ``sketch_feats`` (break the build-time
sketch quantiser's outward-rounding invariant, search/index.py — the
admissibility spot-check covers the tier-(-1) bound because the seeds'
running-max ``pre`` includes the dequantised sketch term, so an
inward-rounded store trips it like any lying tier).
"""

from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_INF = jnp.inf


class GuardWarning(UserWarning):
    """Category for every guard / preflight / hygiene warning."""


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Which invariant checks run, and the degradation policy.

    Default-on: the checks are priced (``guard_overhead_*`` bench rows,
    CI-guarded <= 5% on the bound pass) and cheap enough to leave on in
    serving.  ``REPRO_FORCE_GUARDS=1`` in the environment forces every
    check on regardless of the config (the CI fault-injection job).

    Attributes:
      enabled: master switch; ``False`` makes every guard a no-op and
        the guarded paths bit-identical to the unguarded ones.
      admissibility: sampled ``LB <= DTW`` spot-checks (cascade seeds +
        engine rounds).
      conservation: compaction distinct-count + scatter-monotonicity.
      accounting: engine ``n_dtw`` totals vs the independent mirror and
        the ``k <= n_dtw <= N`` bounds.
      finite_gates: NaN/+inf tier outputs gated to -inf (degrade to
        verification), NaN DTW outputs gated to +inf, both counted.
      rtol / atol: float tolerance of the admissibility comparison
        (bounds and DTW are sums of squares accumulated in different
        orders; 1-ulp re-association must not trip the guard).
      degrade: host-side re-serve via reference brute force when a
        trigger guard (admissibility / conservation / accounting /
        NaN-DTW) trips on concrete inputs (degradation ladder layer 2).
    """

    enabled: bool = True
    admissibility: bool = True
    conservation: bool = True
    accounting: bool = True
    finite_gates: bool = True
    rtol: float = 1e-4
    atol: float = 1e-5
    degrade: bool = True


_FORCED = GuardConfig()


def resolve_guards(cfg: GuardConfig | None) -> GuardConfig:
    """The one place guard configs are normalised: ``None`` means the
    default-on config, and ``REPRO_FORCE_GUARDS=1`` overrides everything
    (so the CI fault-injection job cannot be accidentally disarmed)."""
    if os.environ.get("REPRO_FORCE_GUARDS", "") not in ("", "0"):
        return _FORCED
    return cfg if cfg is not None else GuardConfig()


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------

_VEC_FIELDS = (
    "admiss_checked",
    "admiss_viol",
    "admiss_gap",
    "conserve_checked",
    "conserve_viol",
    "account_checked",
    "account_viol",
    "nonfinite_bounds",
    "nonfinite_dtw",
    "hygiene_values",
    "hygiene_series",
    "hygiene_flat",
    "degraded",
)

# fields that *trip* the degradation ladder (layer 2) when > 0.
# ``nonfinite_dtw`` is a trigger: a NaN verification value is gated to
# +inf, and +inf there may *exclude a true neighbour* — only a rerun
# through the reference kernels can restore soundness.  The
# ``nonfinite_bounds`` gate (-inf = "must verify") IS exactness-
# preserving, so it — and the hygiene counters, which report what the
# boundary already handled — stay containment/reporting only.
_TRIP_FIELDS = (
    "admiss_viol", "conserve_viol", "account_viol", "nonfinite_dtw",
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GuardReport:
    """Structured guard outcome for one executor pass (pytree).

    Every field is a float32 scalar array, so the struct traces under
    ``jit`` / ``shard_map`` and merges across shards exactly like
    ``TierStats``: counts and ``*_checked`` totals add (``psum``), the
    admissibility ``gap`` maxes (``pmax``) — ``merge`` does the local
    composition, ``to_vector``/``from_vector`` give the flat ``(G,)``
    form that crosses ``shard_map`` output specs without pytree
    ceremony.

    Attributes:
      admiss_checked / admiss_viol: sampled ``LB <= DTW`` comparisons
        performed / failed (beyond ``rtol``/``atol``).
      admiss_gap: the worst observed ``LB - DTW`` overshoot (0 when
        clean) — how badly admissibility was violated, not just whether.
      conserve_checked / conserve_viol: compaction conservation checks
        performed / failed (lost or duplicated survivors, scatter-max
        that *loosened* a bound).
      account_checked / account_viol: engine verification-accounting
        checks performed / failed.
      nonfinite_bounds: tier-output values gated -inf (NaN / +inf).
      nonfinite_dtw: DTW outputs gated +inf (NaN).
      hygiene_values / hygiene_series / hygiene_flat: input-hygiene
        counts (non-finite values, series containing them, zero-variance
        series) found at the ``build_index`` / ``nn_search`` boundary.
      degraded: how many degradation-ladder reruns (layer 2) produced
        this result — > 0 means the engine fell back to reference brute
        force after a tripped guard.
    """

    admiss_checked: Array
    admiss_viol: Array
    admiss_gap: Array
    conserve_checked: Array
    conserve_viol: Array
    account_checked: Array
    account_viol: Array
    nonfinite_bounds: Array
    nonfinite_dtw: Array
    hygiene_values: Array
    hygiene_series: Array
    hygiene_flat: Array
    degraded: Array

    @staticmethod
    def zeros() -> "GuardReport":
        z = jnp.zeros((), jnp.float32)
        return GuardReport(**{f: z for f in _VEC_FIELDS})

    def merge(self, other: "GuardReport") -> "GuardReport":
        """Compose two reports: counts add, the admissibility gap maxes."""
        vals = {}
        for f in _VEC_FIELDS:
            a, b = getattr(self, f), getattr(other, f)
            vals[f] = jnp.maximum(a, b) if f == "admiss_gap" else a + b
        return GuardReport(**vals)

    def to_vector(self) -> Array:
        """Flat ``(G,)`` float32 form (fixed field order) — the shape
        that crosses ``shard_map`` output specs and psum collectives."""
        return jnp.stack(
            [jnp.asarray(getattr(self, f), jnp.float32) for f in _VEC_FIELDS]
        )

    @staticmethod
    def from_vector(v: Array) -> "GuardReport":
        return GuardReport(**{f: v[i] for i, f in enumerate(_VEC_FIELDS)})

    # -- host-side readout --------------------------------------------------

    def tripped(self) -> tuple[str, ...]:
        """Names of the guards whose violation counters are non-zero
        (host sync).  These are the degradation-ladder triggers; the
        nonfinite/hygiene counters are containment-only and do not
        appear here (read them off ``summary()``)."""
        return tuple(
            f for f in _TRIP_FIELDS if float(np.asarray(getattr(self, f))) > 0
        )

    def ok(self) -> bool:
        return not self.tripped()

    def summary(self) -> str:
        """One-line human-readable guard readout (host-side)."""
        g = {f: float(np.asarray(getattr(self, f))) for f in _VEC_FIELDS}
        parts = [
            f"admissibility {g['admiss_viol']:.0f}/{g['admiss_checked']:.0f}"
            + (f" (gap {g['admiss_gap']:.3g})" if g["admiss_viol"] else ""),
            f"conservation {g['conserve_viol']:.0f}/"
            f"{g['conserve_checked']:.0f}",
            f"accounting {g['account_viol']:.0f}/{g['account_checked']:.0f}",
        ]
        gated = g["nonfinite_bounds"] + g["nonfinite_dtw"]
        if gated:
            parts.append(
                f"gated {g['nonfinite_bounds']:.0f} bounds / "
                f"{g['nonfinite_dtw']:.0f} dtw"
            )
        hyg = g["hygiene_values"] + g["hygiene_flat"]
        if hyg:
            parts.append(
                f"hygiene {g['hygiene_values']:.0f} values in "
                f"{g['hygiene_series']:.0f} series, "
                f"{g['hygiene_flat']:.0f} flat"
            )
        if g["degraded"]:
            parts.append(f"degraded x{g['degraded']:.0f} (jnp ref rerun)")
        status = "TRIPPED " + ",".join(self.tripped()) if self.tripped() \
            else "ok"
        return f"guards[{status}]: " + "   ".join(parts)


# ---------------------------------------------------------------------------
# the checks (pure jnp — safe under jit / shard_map)
# ---------------------------------------------------------------------------


def finite_gate_bounds(t: Array) -> tuple[Array, Array]:
    """Gate a tier's bound output: NaN / +inf values become ``-inf``.

    ``-inf`` is the running-max identity *and* a trivially valid lower
    bound, so a poisoned bound degrades to "verify this candidate"
    (safe) instead of "never verify it" (wrong answers).  ``-inf``
    inputs pass through — they are the legitimate dead-slot identity
    the liveness kernels emit.  Returns ``(gated, n_gated)``.
    """
    bad = jnp.isnan(t) | jnp.isposinf(t)
    return jnp.where(bad, -_INF, t), jnp.sum(bad).astype(jnp.float32)


def finite_gate_dtw(d: Array, valid: Array | None = None
                    ) -> tuple[Array, Array]:
    """Gate DTW outputs: NaN becomes ``+inf`` ("treat as unverifiable"),
    counted so the host-side ladder knows verification values were
    unsound.  ``+inf`` inputs pass through — they are the legitimate
    early-abandon result.  ``valid`` restricts the count to live slots.
    """
    bad = jnp.isnan(d)
    n = bad if valid is None else (bad & valid)
    return jnp.where(bad, _INF, d), jnp.sum(n).astype(jnp.float32)


def verification_eligible(slb: Array) -> Array:
    """Which sorted-bound entries the engine may verify.

    The engine masks verified seeds and excluded candidates by setting
    their bound to exactly ``+inf`` — that is the *only* value that
    legitimately means "never verify".  Everything else, including NaN
    (a poisoned bound) and ``-inf`` (a gated one), must stay eligible:
    the old ``isfinite`` filter silently converted a non-finite bound
    into "never verify this candidate", turning a poisoned bound into
    missing neighbours.  Degrading to verification is always safe.
    """
    return ~jnp.isposinf(slb)


def admissibility_check(
    lb: Array, d: Array, rtol: float, atol: float,
    valid: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Sampled ``LB <= DTW`` spot-check on pairs with exact DTW values.

    Only pairs whose DTW is finite participate (+inf = early-abandoned,
    nothing to compare; NaN compares ``False`` and is the finite gate's
    problem).  Returns ``(checked, viol, gap)`` — comparisons made,
    violations beyond tolerance, and the worst ``LB - DTW`` overshoot.
    """
    fin = jnp.isfinite(d) & jnp.isfinite(lb)
    if valid is not None:
        fin = fin & valid
    over = jnp.where(fin, lb - d, -_INF)
    viol = jnp.sum(fin & (lb > d * (1.0 + rtol) + atol))
    return (
        jnp.sum(fin).astype(jnp.float32),
        viol.astype(jnp.float32),
        jnp.maximum(jnp.max(over, initial=-_INF), 0.0).astype(jnp.float32),
    )


def conservation_check(cand: Array, n: int) -> tuple[Array, Array]:
    """Survivor-mass conservation across gather-compaction.

    The compaction's ``top_k`` must hand the pairwise tiers exactly
    ``W`` *distinct* candidates per query — a duplicated index means a
    live candidate was silently dropped from the pack (the shard_map
    miscompile shape: no error, one fewer real survivor refined).
    Returns ``(checked, viol)`` with one check per query.
    """
    Q, W = cand.shape
    marks = jnp.zeros((Q, n), jnp.int32).at[
        jnp.arange(Q)[:, None], cand
    ].add(1)
    distinct = jnp.sum(marks > 0, axis=1)
    return (
        jnp.asarray(float(Q), jnp.float32),
        jnp.sum(distinct != W).astype(jnp.float32),
    )


def scatter_monotone_check(lb_before: Array, lb_after: Array
                           ) -> tuple[Array, Array]:
    """The scatter-max back into the bound matrix can only tighten:
    ``lb_after >= lb_before`` everywhere (running max is monotone by
    construction — only a miscompiled gather/scatter breaks it).
    NaN entries compare ``False`` on both sides and are the finite
    gate's to count.  Returns ``(checked, viol)``, one check per query.
    """
    viol = jnp.sum(lb_after < lb_before)
    return (
        jnp.asarray(float(lb_before.shape[0]), jnp.float32),
        viol.astype(jnp.float32),
    )


# ---------------------------------------------------------------------------
# input hygiene (degradation ladder layer 3 — host-side, boundary only)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HygieneReport:
    """Host-side input-hygiene outcome (plain ints — never traced)."""

    bad_values: int = 0
    bad_series: int = 0
    flat_series: int = 0

    def any(self) -> bool:
        return bool(self.bad_values or self.flat_series)


def validate_series(
    x,
    *,
    name: str = "series",
    sanitize: bool = False,
    check_flat: bool = False,
) -> tuple[Array, HygieneReport]:
    """Reject or sanitize NaN/Inf values and zero-variance series.

    Host-side only (callers gate on concrete inputs).  Without
    ``sanitize`` any non-finite value — or, with ``check_flat``, any
    zero-variance series (z-norm turns those into all-zeros, which then
    matches *every* flat query at distance 0) — raises ``ValueError``
    naming the offending rows.  With ``sanitize=True`` non-finite values
    are masked to the series' finite mean (0.0 when nothing is finite),
    flat series are left numerically unchanged (``znorm``'s epsilon maps
    them to zeros), and everything found is counted into the returned
    ``HygieneReport`` plus a ``GuardWarning``.
    """
    arr = np.asarray(x, np.float32)
    bad = ~np.isfinite(arr)
    bad_rows = np.where(bad.any(axis=tuple(range(1, arr.ndim))))[0] \
        if arr.ndim > 1 else np.where(bad)[0]
    flat_rows = np.array([], np.int64)
    if check_flat and arr.ndim > 1:
        span = arr.max(axis=-1) - arr.min(axis=-1)
        span = np.where(np.isfinite(span), span, np.inf)  # bad rows != flat
        flat_rows = np.where(span == 0.0)[0]
    report = HygieneReport(
        bad_values=int(bad.sum()),
        bad_series=int(bad_rows.size),
        flat_series=int(flat_rows.size),
    )
    if not report.any():
        # clean path: hand back the caller's own array when it is already
        # on-device — validation must not cost a host->device copy
        out = x if isinstance(x, jax.Array) else jnp.asarray(arr)
        return out, report
    if not sanitize:
        msgs = []
        if report.bad_values:
            msgs.append(
                f"{report.bad_values} non-finite values in "
                f"{report.bad_series} {name} rows "
                f"(first: {bad_rows[:8].tolist()})"
            )
        if report.flat_series:
            msgs.append(
                f"{report.flat_series} zero-variance {name} rows "
                f"(first: {flat_rows[:8].tolist()}) — z-norm would map "
                "these to all-zeros"
            )
        raise ValueError(
            "; ".join(msgs)
            + f"; pass sanitize=True to mask and report instead"
        )
    if report.bad_values:
        clean = np.where(bad, np.nan, arr)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)  # all-NaN rows
            fill = np.nanmean(clean, axis=-1, keepdims=True)
        fill = np.where(np.isfinite(fill), fill, 0.0)
        arr = np.where(bad, np.broadcast_to(fill, arr.shape), arr)
    warnings.warn(
        f"sanitized {name}: masked {report.bad_values} non-finite values "
        f"in {report.bad_series} rows"
        + (f", {report.flat_series} zero-variance rows kept (z-norm maps "
           "them to zeros)" if report.flat_series else ""),
        GuardWarning,
        stacklevel=2,
    )
    return jnp.asarray(arr), report


def hygiene_to_report(h: HygieneReport) -> GuardReport:
    """Lift host-side hygiene counts into the pytree report so one
    ``GuardReport`` tells the whole story of a search."""
    r = GuardReport.zeros()
    return dataclasses.replace(
        r,
        hygiene_values=jnp.asarray(float(h.bad_values), jnp.float32),
        hygiene_series=jnp.asarray(float(h.bad_series), jnp.float32),
        hygiene_flat=jnp.asarray(float(h.flat_series), jnp.float32),
    )


# ---------------------------------------------------------------------------
# fault-injection seams (populated only by testing/faults.py)
# ---------------------------------------------------------------------------

_FAULT_HOOKS: dict[str, Callable] = {}


def fault_hook(name: str) -> Callable | None:
    """The injection seam: production call sites do one dict lookup that
    is ``None`` outside the fault harness.  Never install hooks here
    directly — use ``repro.testing.faults.inject`` so teardown is
    guaranteed."""
    return _FAULT_HOOKS.get(name)


# ---------------------------------------------------------------------------
# preflight (degradation ladder layer 0 — prove the compiled path)
# ---------------------------------------------------------------------------

_PREFLIGHT_CACHE: dict = {}
_WARN_COUNTS: dict[str, int] = {}


def warn_once(key: str, message: str) -> bool:
    """Emit a ``GuardWarning`` exactly once per process per key.

    Returns ``True`` when the warning actually fired — the promoted
    miscompile test asserts the once-per-process contract through
    ``warn_count``.
    """
    n = _WARN_COUNTS.get(key, 0)
    _WARN_COUNTS[key] = n + 1
    if n == 0:
        warnings.warn(message, GuardWarning, stacklevel=3)
        return True
    return False


def warn_count(key: str) -> int:
    """How many times ``warn_once(key, ...)`` was *requested* (the
    warning itself fired at most once)."""
    return _WARN_COUNTS.get(key, 0)


def preflight_clear() -> None:
    """Drop cached preflight verdicts and warning bookkeeping (tests)."""
    _PREFLIGHT_CACHE.clear()
    _WARN_COUNTS.clear()


def _canary_store(n: int, length: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    series = rng.normal(size=(n, length)).astype(np.float32)
    queries = rng.normal(size=(max(2, n // 16), length)).astype(np.float32)
    return series, queries


def preflight_engine() -> bool:
    """Single-device self-test: the jitted engine must equal brute force
    on a canary store.  Cached per process; ``build_index(preflight=
    True)`` runs it before a store starts serving.  Returns ``True``
    when the compiled path is exact; on mismatch warns (once) and
    returns ``False`` — callers stay on the guarded/degraded paths.
    """
    key = ("engine", jax.__version__)
    hit = _PREFLIGHT_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.search.engine import EngineConfig, brute_force, nn_search
    from repro.search.cascade import CascadeConfig
    from repro.search.index import build_index

    series, queries = _canary_store(32, 16)
    idx = build_index(series, 4)
    cfg = EngineConfig(
        cascade=CascadeConfig(w=4, v=4, candidate_chunk=8, use_pallas=False),
        verify_chunk=4, k=2,
    )
    got = jax.jit(lambda q: nn_search(idx, q, cfg).dists)(
        jnp.asarray(queries)
    )
    want, _ = brute_force(idx, queries, 4, k=2, use_pallas=False)
    ok = bool(np.allclose(np.asarray(got), np.asarray(want), rtol=1e-4))
    if not ok:
        warn_once(
            "preflight_engine",
            "preflight: jitted single-device engine does not match brute "
            "force on the canary store — keep runtime guards on and "
            "expect degradation reruns",
        )
    _PREFLIGHT_CACHE[key] = ok
    return ok


def preflight_shard_map(
    mesh,
    data_axes: tuple[str, ...] = ("data",),
    query_axis: str = "model",
) -> bool:
    """Detect the ``jit(shard_map(engine while_loop))`` miscompile.

    Runs the *real* distributed search step — the minimal while_loop
    canary does NOT reproduce the jax 0.4.x bug; the engine's
    data-dependent verification loop does, even at N=32, L=16 — jitted,
    on the given mesh, against host-side brute force.  Whether a
    dropped candidate actually changes the returned top-k is
    data-dependent, so the canary sweeps several seeded stores (on the
    affected jax versions roughly two in three trip) and reports safe
    only if *every* one is exact.  Returns ``True`` when the jitted
    path is exact (jax >= 0.6), ``False`` on the 0.4.x miscompile.
    Cached per (mesh shape, axes, jax version), so a process pays the
    ~seconds canary once; ``make_distributed_search`` consults this to
    auto-select the safe unjitted path (replacing the docs-only
    workaround).
    """
    axes = tuple(data_axes)
    key = (
        "shard_map_while",
        tuple(sorted(mesh.shape.items())),
        axes,
        query_axis,
        jax.__version__,
    )
    hit = _PREFLIGHT_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.search.cascade import CascadeConfig
    from repro.search.distributed import _build_step, shard_index
    from repro.search.engine import EngineConfig, brute_force
    from repro.search.index import build_index

    D = 1
    for a in axes:
        D *= mesh.shape[a]
    Qsh = mesh.shape[query_axis]
    n_local, L, w, k = 8, 16, 4, 2
    Q = Qsh * 4
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, candidate_chunk=n_local,
                              use_pallas=False),
        verify_chunk=4, k=k,
    )
    step = None
    ok = True
    for seed in range(3):
        rng = np.random.default_rng(seed)
        series = rng.normal(size=(D * n_local, L)).astype(np.float32)
        queries = rng.normal(size=(Q, L)).astype(np.float32)
        idx = build_index(series, w)
        sidx = shard_index(mesh, idx, axes)
        if step is None:
            step = jax.jit(_build_step(
                mesh, cfg, data_axes=axes, query_axis=query_axis))
        try:
            got, _, _ = step(
                sidx.series, sidx.labels, sidx.upper, sidx.lower,
                sidx.kim, sidx.kim_ok, jnp.asarray(queries),
            )
            want, _ = brute_force(idx, queries, w, k=k, use_pallas=False)
            ok = bool(np.allclose(np.asarray(got), np.asarray(want),
                                  rtol=1e-4))
        except Exception:   # a jit that *fails loudly* is also unsafe
            ok = False
        if not ok:
            break
    _PREFLIGHT_CACHE[key] = ok
    return ok
