"""Synthetic LM token pipeline with checkpointable cursor state.

Deterministic, seekable stream: batch ``i`` is a pure function of
``(seed, i)``, so restoring ``cursor`` from a checkpoint resumes the exact
stream — the data-pipeline half of fault tolerance (DESIGN.md SS6).
The distribution is a Zipf-ish unigram mix with Markov bigram structure so
the loss curve is non-trivial (a pure-uniform stream has nothing to learn).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    cursor: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # sparse "grammar": each token prefers a handful of successors
        self._succ = rng.integers(0, self.vocab, size=(self.vocab, 4))

    def next_batch(self) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.cursor))
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab, size=self.batch, p=self._unigram)
        follow = rng.random(size=(self.batch, self.seq_len)) < 0.7
        succ_pick = rng.integers(0, 4, size=(self.batch, self.seq_len))
        fresh = rng.choice(
            self.vocab, size=(self.batch, self.seq_len), p=self._unigram
        )
        for t in range(self.seq_len):
            nxt = self._succ[toks[:, t], succ_pick[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, fresh[:, t])
        self.cursor += 1
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }

    def state(self) -> dict:
        return {"seed": self.seed, "cursor": self.cursor}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.seed, "pipeline seed mismatch"
        self.cursor = int(state["cursor"])
