"""Data substrate: synthetic UCR-like series + LM token pipeline."""

from repro.data.synthetic import Dataset, make_dataset, random_pairs

__all__ = ["Dataset", "make_dataset", "random_pairs"]
