"""Synthetic UCR-like time-series classification data.

No network access in this container, so we generate datasets with the same
statistical character the UCR archive stresses: per-class smooth prototypes,
instances that are *time-warped* copies (random monotone warp maps) with
additive noise and amplitude jitter, z-normalised (UCR convention).  Warping
is what makes DTW the right distance, and window size the knob — matching
the paper's experimental regime.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    x_train: np.ndarray  # (N, L) float32, z-normalised
    y_train: np.ndarray  # (N,) int32
    x_test: np.ndarray   # (T, L)
    y_test: np.ndarray   # (T,)

    @property
    def length(self) -> int:
        return self.x_train.shape[1]

    @property
    def n_classes(self) -> int:
        return int(self.y_train.max()) + 1


def _smooth(x: np.ndarray, k: int) -> np.ndarray:
    ker = np.ones(k) / k
    return np.convolve(x, ker, mode="same")


def _znorm(x: np.ndarray) -> np.ndarray:
    return (x - x.mean(-1, keepdims=True)) / (x.std(-1, keepdims=True) + 1e-8)


def _prototype(rng: np.random.Generator, L: int) -> np.ndarray:
    walk = np.cumsum(rng.normal(size=L + 16))
    return _znorm(_smooth(walk, 9)[8 : 8 + L])


def _warp(rng: np.random.Generator, proto: np.ndarray, strength: float) -> np.ndarray:
    """Random monotone time warp: resample through a jittered knot map."""
    L = len(proto)
    n_knots = 6
    knots_x = np.linspace(0, 1, n_knots)
    knots_y = knots_x + rng.normal(scale=strength / n_knots, size=n_knots)
    knots_y[0], knots_y[-1] = 0.0, 1.0
    knots_y = np.maximum.accumulate(knots_y)
    knots_y /= max(knots_y[-1], 1e-9)
    t = np.interp(np.linspace(0, 1, L), knots_x, knots_y)
    return np.interp(t * (L - 1), np.arange(L), proto)


def make_dataset(
    n_classes: int = 4,
    n_train_per_class: int = 25,
    n_test_per_class: int = 10,
    length: int = 128,
    *,
    warp: float = 0.5,
    noise: float = 0.15,
    seed: int = 0,
) -> Dataset:
    """Generate a UCR-like dataset (z-normalised, stratified splits)."""
    rng = np.random.default_rng(seed)
    protos = [_prototype(rng, length) for _ in range(n_classes)]

    def sample(cls: int) -> np.ndarray:
        x = _warp(rng, protos[cls], warp)
        x = x * (1.0 + rng.normal(scale=0.1))
        x = x + rng.normal(scale=noise, size=length)
        return _znorm(x)

    xs_tr, ys_tr, xs_te, ys_te = [], [], [], []
    for c in range(n_classes):
        for _ in range(n_train_per_class):
            xs_tr.append(sample(c))
            ys_tr.append(c)
        for _ in range(n_test_per_class):
            xs_te.append(sample(c))
            ys_te.append(c)
    perm = rng.permutation(len(xs_tr))
    x_train = np.asarray(xs_tr, np.float32)[perm]
    y_train = np.asarray(ys_tr, np.int32)[perm]
    return Dataset(
        x_train=x_train,
        y_train=y_train,
        x_test=np.asarray(xs_te, np.float32),
        y_test=np.asarray(ys_te, np.int32),
    )


def random_pairs(
    n_pairs: int, length: int, *, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Random z-normalised series pairs (the paper's Fig. 1 protocol)."""
    rng = np.random.default_rng(seed)
    a = np.cumsum(rng.normal(size=(n_pairs, length)), axis=1)
    b = np.cumsum(rng.normal(size=(n_pairs, length)), axis=1)
    return _znorm(a).astype(np.float32), _znorm(b).astype(np.float32)
