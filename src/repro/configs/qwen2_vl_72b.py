"""qwen2-vl-72b [vlm] — 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064,
M-RoPE (t/h/w 16/24/24), dynamic resolution.  The vision patch frontend is a
STUB: input_specs() provides precomputed patch embeddings for the vision
prefix plus (B, 3, S) M-RoPE position streams.  [arXiv:2409.12191; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    mrope_sections=(16, 24, 24),
    vision_prefix=1024,
    rope_theta=1_000_000.0,
    qkv_bias=True,
)
