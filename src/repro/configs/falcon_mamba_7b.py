"""falcon-mamba-7b [ssm] — 64L d=4096, attention-free Mamba-1,
d_inner=8192 ssm_state=16, vocab=65024.  [arXiv:2410.05355]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,               # no separate FFN: mamba block is the layer
    vocab=65024,
    attn_every=-1,
    d_inner=8192,
    ssm_state=16,
    conv_width=4,
)
