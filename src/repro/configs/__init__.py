"""Configs: 10 assigned architectures + shapes + the paper's search config."""
