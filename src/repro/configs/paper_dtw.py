"""The paper's own workload as a dry-run cell: pod-scale NN-DTW search.

A million-series candidate store (the regime the paper's introduction says
NN-DTW "does not scale" to) sharded over the data axes, a query batch over
the model axis, LB_ENHANCED^4 cascade + banded-DTW verification.  W = 0.3L
matches the paper's Fig. 1 protocol.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperSearchConfig:
    name: str = "search_1m"
    n_store: int = 1_048_576       # 2^20 candidate series
    length: int = 512
    n_queries: int = 2048
    w: int = 154                   # 0.3 * L (paper Fig. 1)
    v: int = 4                     # the paper's recommended variant
    k: int = 1
    verify_chunk: int = 64
    candidate_chunk: int = 512
    expected_verify: int = 64      # expected DTW verifications per query


PAPER_SEARCH = PaperSearchConfig()
