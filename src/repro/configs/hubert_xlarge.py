"""hubert-xlarge [audio] — 48L d=1280 16H d_ff=5120 vocab=504, encoder-only
(w2v2-style backbone).  The conv waveform frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, S, d); the head predicts the 504
k-means target units per frame.  [arXiv:2106.07447]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,          # encoder-only
    embed_inputs=False,    # frontend stub provides frame embeddings
    act="gelu",
)
