"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``; the four assigned input
shapes are ``ShapeConfig``s.  ``cells()`` enumerates the (arch x shape)
dry-run grid with per-cell applicability (encoder archs have no decode;
``long_500k`` requires sub-quadratic context handling — DESIGN.md SS5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Static per-layer structure (drives the scanned block body)."""

    mixer: str = "attn"          # "attn" | "mamba"
    window: int | None = None    # sliding-window size for local attention
    moe: bool = False            # routed-MoE FFN (else dense MLP)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None

    # attention features
    causal: bool = True
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None     # used by local layers
    local_global_period: int = 0          # 2 -> alternate local/global
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] | None = None

    # input modality
    embed_inputs: bool = True             # False: frontend stub provides embeddings
    vision_prefix: int = 0                # VLM: patch-embedding positions

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int | None = None
    moe_period: int = 1                   # MoE every k-th layer
    first_dense: int = 0                  # leading dense layers (deepseek)

    # SSM / hybrid
    attn_every: int = 0                   # 0: all attn; -1: all mamba; k: attn at i%k==offset
    attn_offset: int = 4
    d_inner: int | None = None
    ssm_state: int = 16
    conv_width: int = 4
    dt_rank: int | None = None

    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank if self.dt_rank else max(1, self.d_model // 16)

    @property
    def d_inner_(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    def layer_spec(self, i: int) -> LayerSpec:
        if self.attn_every == -1:
            mixer = "mamba"
        elif self.attn_every > 0:
            mixer = "attn" if i % self.attn_every == self.attn_offset else "mamba"
        else:
            mixer = "attn"
        window = None
        if mixer == "attn" and self.sliding_window:
            if self.local_global_period:
                if i % self.local_global_period == 0:   # local first (gemma2)
                    window = self.sliding_window
            else:
                window = self.sliding_window
        moe = (
            self.n_experts > 0
            and i >= self.first_dense
            and (i % self.moe_period == (self.moe_period - 1) if self.moe_period > 1 else True)
        )
        return LayerSpec(mixer=mixer, window=window, moe=moe)

    def layout(self) -> tuple[list[LayerSpec], list[LayerSpec], int]:
        """(prelude specs, period specs, n_repeat) for the scanned stack."""
        specs = [self.layer_spec(i) for i in range(self.n_layers)]
        prelude = specs[: self.first_dense]
        rest = specs[self.first_dense :]
        # find the smallest period that tiles the remaining layers
        for period in (1, 2, 4, 8):
            if len(rest) % period:
                continue
            pat = rest[:period]
            if all(
                rest[j] == pat[j % period] for j in range(len(rest))
            ):
                return prelude, pat, len(rest) // period
        raise ValueError(f"{self.name}: no periodic layout found")

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, dh = self.d_model, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            spec = self.layer_spec(i)
            if spec.mixer == "attn":
                total += d * dh * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * dh * d
            else:
                din, n, r = self.d_inner_, self.ssm_state, self.dt_rank_
                total += d * 2 * din + din * (r + 2 * n) + r * din
                total += din * (n + 1 + self.conv_width) + din * d
            if spec.moe:
                fe = self.d_expert or self.d_ff
                total += d * self.n_experts_padded
                total += self.n_experts * 3 * d * fe
                total += self.n_shared_experts * 3 * d * fe
            else:
                mult = 3 if self.act == "silu" else 2
                total += mult * d * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        fe = self.d_expert or self.d_ff
        inactive = 0
        for i in range(self.n_layers):
            if self.layer_spec(i).moe:
                inactive += (self.n_experts - self.top_k) * 3 * d * fe
        return self.n_params() - inactive

    @property
    def n_experts_padded(self) -> int:
        """Experts padded to a multiple of 16 for clean EP sharding."""
        return int(math.ceil(self.n_experts / 16) * 16) if self.n_experts else 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicability(arch: ArchConfig, shape: ShapeConfig) -> str | None:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.kind == "decode" and not arch.causal:
        return "encoder-only architecture: no autoregressive decode step"
    if shape.name == "long_500k":
        sub_quadratic = (
            arch.attn_every != 0          # ssm / hybrid
            or arch.sliding_window is not None   # local(+global) attention
        )
        if not sub_quadratic:
            return "pure full-attention arch: 512k decode KV excluded (DESIGN.md SS5)"
    return None


def cells(arch: ArchConfig) -> Iterable[tuple[ShapeConfig, str | None]]:
    for s in SHAPES.values():
        yield s, shape_applicability(arch, s)
