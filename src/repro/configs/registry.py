"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses

from repro.configs import (
    deepseek_moe_16b,
    falcon_mamba_7b,
    gemma2_2b,
    granite_8b,
    granite_20b,
    hubert_xlarge,
    jamba_1_5_large_398b,
    qwen2_5_3b,
    qwen2_moe_a2_7b,
    qwen2_vl_72b,
)
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, cells

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_moe_a2_7b,
        deepseek_moe_16b,
        granite_20b,
        gemma2_2b,
        qwen2_5_3b,
        granite_8b,
        hubert_xlarge,
        falcon_mamba_7b,
        jamba_1_5_large_398b,
        qwen2_vl_72b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to smoke-test size while preserving its *structure*
    (same family, same layer pattern, same divisibility properties)."""
    prelude, period, _ = cfg.layout()
    n_layers = cfg.first_dense + 2 * len(period)     # two periods
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=16,
        d_ff=cfg.d_ff and 128,
        vocab=256,
        d_expert=32 if cfg.d_expert else None,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_inner=128 if cfg.d_inner else None,
        dt_rank=8,
        sliding_window=8 if cfg.sliding_window else None,
        vision_prefix=4 if cfg.vision_prefix else 0,
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else None,
    )


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "cells", "get_arch", "reduced"]
