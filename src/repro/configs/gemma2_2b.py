"""gemma2-2b [dense] — 26L d=2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
local(4k)+global alternating attention, logit softcaps.  [arXiv:2408.00118; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256000,
    d_head=256,
    sliding_window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)
