"""jamba-1.5-large-398b [hybrid] — 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, Mamba+attention 1:7 interleave, MoE 16e top-2 on every other
layer.  [arXiv:2403.19887; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    attn_every=8,          # one attention layer per 8 (1:7 with mamba)
    attn_offset=4,
    d_inner=16384,
    ssm_state=16,
    n_experts=16,
    n_shared_experts=0,
    top_k=2,
    d_expert=24576,
    moe_period=2,          # MoE every other layer
)
