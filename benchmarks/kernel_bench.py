"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp refs.

On this CPU container the interpret-mode numbers measure *semantics*, not
TPU performance — the derived column carries the roofline-relevant byte/
flop counts per call so EXPERIMENTS.md can relate them to the v5e targets.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.data import random_pairs
from repro.kernels import ref
from repro.kernels.ops import envelope_op, lb_enhanced_op, lb_keogh_op


def kernel_rows() -> list[str]:
    rows = []
    Q, C, L, w, v = 16, 256, 128, 38, 4
    q, c = random_pairs(max(Q, C), L, seed=1)
    qj = jnp.asarray(q[:Q])
    cj = jnp.asarray(c[:C])
    u, lo = envelope_op(cj, w)

    sec = time_fn(lambda b: ref.envelope_ref(b, w), cj)
    rows.append(
        f"envelope_jnp_{C}x{L},{1e6 * sec / C:.2f},"
        f"bytes_per_series={L * 4 * 3}"
    )
    sec = time_fn(lambda a, b, e1, e2: ref.lb_keogh_ref(a, e1, e2), qj, cj, u, lo)
    rows.append(
        f"lb_keogh_jnp_{Q}x{C}x{L},{1e6 * sec / (Q * C):.3f},"
        f"flops_per_pair={4 * L}"
    )
    sec = time_fn(
        lambda a, b, e1, e2: ref.lb_enhanced_ref(a, b, e1, e2, w, v),
        qj, cj, u, lo,
    )
    rows.append(
        f"lb_enhanced4_jnp_{Q}x{C}x{L},{1e6 * sec / (Q * C):.3f},"
        f"flops_per_pair={4 * L + 4 * v * v}"
    )
    P = 64
    a2, b2 = random_pairs(P, L, seed=2)
    sec = time_fn(lambda x, y: ref.dtw_band_ref(x, y, w), jnp.asarray(a2), jnp.asarray(b2))
    rows.append(
        f"dtw_band_jnp_{P}x{L},{1e6 * sec / P:.1f},"
        f"flops_per_pair={10 * L * min(2 * w + 1, L)}"
    )
    return rows
