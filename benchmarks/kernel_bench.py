"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp refs.

On this CPU container the interpret-mode numbers measure *semantics*, not
TPU performance — the derived column carries the roofline-relevant byte/
flop counts per call so EXPERIMENTS.md can relate them to the v5e targets.

Alongside the CSV rows this module emits ``BENCH_kernels.json``
(name -> us_per_call) so the perf trajectory is machine-readable across
PRs.  The checked-in copy is intentional — it is the per-PR trajectory
record (numbers are container-CPU timings; CI uploads its own run as an
artifact without committing it, and fails if a re-run *loses* keys vs the
previous commit — see .github/workflows/ci.yml).  The ``dtw_band`` rows
sweep ``w/L in {0.05, 0.1, 0.3, 1.0}`` at fixed L: with the band-packed
O(L*W) recurrence the per-call time should grow ~linearly in w, where the
seed O(L^2) wavefront was flat (and ~10x slower at w = 0.1L).

PR 2 rows (the survivor hot path):
  * ``lb_enhanced_pairwise_{jnp,pallas}_*`` — staged tier-2 refinement
    over packed (P, L) survivor pairs: PR 1's vmapped-jnp path vs the
    dedicated pairwise Pallas kernel.
  * ``dtw_band_{pr1,ee}_*_{nocut,cut}`` — PR 1's per-step lane-poisoning
    DTW kernel vs the (pair_tile, row_block) early-exit grid, with and
    without an aggressive per-pair cutoff (every lane abandons in the
    first block, so ``ee``+``cut`` measures genuinely skipped sweeps).
  * ``*_speedup_vs_pr1`` — derived ratios (PR 1 path time / new path
    time) so the trajectory is self-describing without cross-referencing
    old commits.

PR 3 rows (scheduler observability — bound-ordered verification):
  * ``sched_{bound,index}_L*_w*_tile_skip_rate`` — fraction of the DTW
    kernel's (pair_tile, row_block) grid cells skipped on a verification
    round's flat batch under each schedule, computed with the host-side
    liveness mirror (core.dtw.dtw_band_death_blocks) at the *engine's
    real geometry per schedule*: bound-ordered rounds now also shrink
    their pair tile (``tiling.sched_pair_tile`` — PR 4), index rounds
    keep the kernel default.  The uplift is what converts the per-tile
    liveness exit into an effective per-pair early exit, and should
    surface in the ``dtw_band_ee_*_speedup_vs_pr1`` trajectory on real
    hardware.
  * ``sched_{bound,index}_L*_w*_n_dtw`` — total engine verifications under
    each schedule on the same workload.  The schedule is a packing
    permutation only, so these two must stay equal (the property tests
    enforce per-query equality; the bench records the totals so the
    trajectory proves it too).

PR 4 rows (streaming DTW + per-round tile sizing):
  * ``dtw_band_stream_L{2048,8192,32768,65536}_w*_{nocut,cut}`` — the
    HBM-resident streaming DMA pipeline across the old ``_DTW_MAX_L``
    ceiling (16384): per-call time without a cutoff and with an
    aggressive one (every lane abandons in the first row blocks, so the
    ``cut`` rows measure skipped sweeps *and* skipped DMA issue).
    ``*_cut_speedup_vs_nocut`` are the derived cutoff speedups.
  * ``dtw_band_stream_L2048_w205_speedup_vs_resident`` — streaming vs the
    VMEM-resident grid at a length residency handles fine: the no-
    regression guard for the DMA pipeline (>= ~0.9 means the pipeline
    costs < 10% where residency was already enough).
  * ``sched_bound_L*_w*_tile128_skip_rate`` — the bound schedule at the
    PR 3 fixed 128-lane tile, kept so the packing-only uplift and the
    tile-sizing uplift stay separable in the trajectory;
    ``sched_bound_L*_w*_round_tile_p`` records the tile the per-round
    policy actually picked.

PR 5 rows (self-tuning tier planner — measured mass/cost plan commits):
  * ``plan_auto_L256_w{26,77}_speedup_vs_static`` — median paired-ratio
    wall-clock of the jitted *bound pass* (``run_plan``: tiers +
    compaction + seed verification, the component the plan rewrite
    changes; the engine's verification loop is bit-identical under the
    conservative profile) under the planner-committed plan vs the static
    default plan, calibration paid once outside the timing — the serving
    story.  The adaptive budget estimator over-provisions this workload
    to the full store width, so the committed right-sized compaction
    (search/planner.py) is real work removed; the absolute guard in
    ci.yml fails the build if the auto plan ever regresses >10% vs
    static.
  * ``plan_auto_L256_w{26,77}_n_dtw`` — total engine verifications under
    the committed plan.  The conservative default profile only removes
    measured-idle work, so these equal the static plan's count (the
    planner-exactness property tests pin the per-query version).
  * ``plan_auto_L256_w{26,77}_tier_mass`` — total measured realised
    pruning mass (pairs whose running bound crossed the seed threshold)
    from the calibration stats: the numerator of the mass/cost ratios
    the decision is made from.
  * ``plan_auto_L256_w256_n_dropped`` — tiers the planner drops at
    w = L on the static-budget workload, where the O(L) pairwise
    bands-refinement tier's realised mass collapses to zero: the
    acceptance row (must stay >= 1, guarded in ci.yml).

PR 8 rows (quantised sketch tier + store-level candidate masking):
  * ``sketch_L256_w{26,77}_speedup_vs_nosketch`` — median paired-ratio
    wall-clock of the jitted bound pass under the planner-committed
    plan with ``use_sketch=True`` vs the committed sketchless plan, on
    a Kim-blind store (shared boundary values pin the O(1) tier's
    first/last terms to zero, shared interior extrema spikes pin its
    max/min terms): the sketchless planner must buy its pruning from
    the O(L) bands tier, the sketch side buys the same mass from the
    O(S) int8 tier.  Must stay >= 0.95 everywhere and > 1 here
    (ci.yml); this is the HBM-scale story — the per-pair bound read
    shrinks from the f32 envelopes to a 32-byte sketch.
  * ``sketch_L256_w{26,77}_tier_mass`` — the sketch tier's measured
    realised pruning mass from the committed decision's stats (the
    evidence the planner kept it on merit, not by fiat).
  * ``sketch_L256_w{26,77}_bytes_per_cand`` — int8 sketch store bytes
    per candidate ((sk_lo + sk_hi) / N; the () f32 scale is
    store-wide).  The acceptance budget is <= 32 (S = 16 segments x 2
    envelopes x 1 byte), guarded in ci.yml.
  * ``mask_dense_skip_frac`` — fraction of the store the build-time
    LOO sketch mask (``build_index(..., mask=True)``) retires outright
    on a store with planted outlier series: dead candidates never
    enter the masked dense tiers or the pairwise slots.  Must stay
    > 0 (ci.yml) — a mask that never kills is dead weight.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.data import random_pairs
from repro.kernels import ref
from repro.kernels.ops import (
    dtw_band_op,
    envelope_op,
    lb_enhanced_pairwise_op,
)

_JSON_PATH = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")

# dtw_band O(L*W) scaling sweep: fixed L, w/L in {0.05, 0.1, 0.3, 1.0}
_DTW_SCALING_L = 1024
_DTW_SCALING_P = 16
_DTW_W_FRACTIONS = (0.05, 0.1, 0.3, 1.0)

# early-exit sweep: smaller L so the interpret-mode kernels stay CI-cheap
_DTW_EE_L = 256
_DTW_EE_P = 16

# scheduler observability: one engine workload, two packing schedules
_SCHED_L = 256
_SCHED_Q = 16
_SCHED_M = 32                      # verify_chunk -> P = Q*M = 512 flat slots
_SCHED_W_FRACTIONS = (0.1, 0.3)

# streaming DTW: lengths across the old _DTW_MAX_L = 16384 ceiling; small
# P + modest w keep the interpret-mode sweeps CI-affordable (time is the
# anti-diagonal count — the pipeline itself is length-independent VMEM)
_STREAM_P = 4
_STREAM_SHAPES = ((2048, 205), (8192, 64), (32768, 64), (65536, 64))


def _stream_records() -> list[dict]:
    """Streaming vs resident dtw_band rows (see module docstring)."""
    from repro.kernels.dtw_band import dtw_band_pallas

    recs = []
    for L, w in _STREAM_SHAPES:
        a, b = random_pairs(_STREAM_P, L, seed=6)
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        # the short L=2048 calls carry the stream-vs-resident ratio — give
        # them enough repeats that the ratio is signal, not scheduler noise
        reps = 9 if L <= 2048 else 3
        sec_no = time_fn(
            lambda x, y, _w=w: dtw_band_pallas(x, y, _w, stream=True,
                                               interpret=True),
            aj, bj, repeats=reps,
        )
        recs.append(dict(
            name=f"dtw_band_stream_L{L}_w{w}_nocut",
            us_per_call=1e6 * sec_no / _STREAM_P,
            derived=f"flops_per_pair={10 * L * min(2 * w + 1, L)}",
        ))
        d_true = dtw_band_pallas(aj, bj, w, stream=True, interpret=True)
        # aggressive cutoff: every lane abandons early, so the cut row
        # measures genuinely skipped sweeps and skipped DMA issue
        cutv = jnp.asarray(d_true) * 0.01
        sec_cut = time_fn(
            lambda x, y, _w=w, _c=cutv: dtw_band_pallas(
                x, y, _w, _c, stream=True, interpret=True),
            aj, bj, repeats=reps,
        )
        recs.append(dict(
            name=f"dtw_band_stream_L{L}_w{w}_cut",
            us_per_call=1e6 * sec_cut / _STREAM_P,
            derived="poisoned tiles skip remaining blocks and DMA issue",
        ))
        recs.append(dict(
            name=f"dtw_band_stream_L{L}_w{w}_cut_speedup_vs_nocut",
            us_per_call=sec_no / sec_cut,
            derived="ratio: full streaming sweep / early-abandoned sweep",
        ))
        if L <= 2048:
            # residency handles this length fine: the DMA pipeline must
            # not cost more than ~10% here (the no-regression guard)
            sec_res = time_fn(
                lambda x, y, _w=w: dtw_band_pallas(x, y, _w,
                                                   interpret=True),
                aj, bj, repeats=reps,
            )
            recs.append(dict(
                name=f"dtw_band_resident_L{L}_w{w}_nocut",
                us_per_call=1e6 * sec_res / _STREAM_P,
                derived="VMEM-resident early-exit grid at the same shape",
            ))
            recs.append(dict(
                name=f"dtw_band_stream_L{L}_w{w}_speedup_vs_resident",
                us_per_call=sec_res / sec_no,
                derived="ratio: resident grid / streaming pipeline "
                        "(>= ~0.9 = pipeline costs < 10% where residency "
                        "was already enough)",
            ))
    return recs


def _sched_records() -> list[dict]:
    """Tile-skip-rate + n_dtw rows for bound-ordered vs stripe packing.

    Replays the engine's verification stream (fixed workload, every round
    from cursor 0 to N, per-query k-th best threaded forward) and asks the
    host-side liveness mirror how many (pair_tile, row_block) grid cells
    the early-exit kernel would skip under each packing, aggregated over
    the stream — round 0 is bound-tight almost everywhere, the doomed tail
    the scheduler exists to cluster shows up from round 1 on.  Fully
    deterministic (seeded data, no timing), so the committed values are
    reproducible bit-for-bit in CI.  Uses the jnp DTW path for the search
    itself (n_dtw semantics are dispatch-independent) so the row stays
    CI-cheap.
    """
    import jax.numpy as jnp

    from repro.core.dtw import (
        dtw_band_death_blocks,
        row_block_policy,
        tile_skip_rate,
    )
    from repro.data import make_dataset
    from repro.kernels.dtw_band import _VMEM_BUDGET
    from repro.kernels.tiling import (
        Wb_pad,
        pick_pair_tile,
        round_up,
        sched_pair_tile,
    )
    from repro.search import (
        CascadeConfig,
        EngineConfig,
        build_index,
        default_plan,
        nn_search,
        staged_bounds,
    )

    recs = []
    Q, L, M, k = _SCHED_Q, _SCHED_L, _SCHED_M, 1
    ds = make_dataset(n_classes=4, n_train_per_class=48, n_test_per_class=4,
                      length=L, seed=11)
    q = jnp.asarray(ds.x_test[:Q])
    for frac in _SCHED_W_FRACTIONS:
        w = max(1, int(round(frac * L)))
        idx = build_index(ds.x_train, w, ds.y_train)
        cascade = CascadeConfig(w=w, use_pallas=False, survivor_budget=64)
        ecfg = EngineConfig(cascade=cascade, verify_chunk=M, k=k)
        for sched in ("bound", "index"):
            res = nn_search(idx, q, ecfg,
                            plan=default_plan(cascade, schedule=sched))
            recs.append(dict(
                name=f"sched_{sched}_L{L}_w{w}_n_dtw",
                us_per_call=float(np.sum(np.array(res.n_dtw))),
                derived="total verifications; schedule-invariant by design",
            ))
        # replay the verification stream round by round
        cres = staged_bounds(q, idx, cascade, k=k)
        qar = jnp.arange(Q)
        kth = jnp.sort(cres.seed_d, axis=1)[:, k - 1]
        lb_order = cres.lb.at[qar[:, None], cres.seed_idx].set(jnp.inf)
        order = jnp.argsort(lb_order, axis=1)
        slb = jnp.take_along_axis(lb_order, order, axis=1)
        P = Q * M
        N = idx.n
        # kernel geometry per schedule: index rounds keep the kernel
        # default tile; bound rounds use the engine's per-round policy
        # (sched_pair_tile) — the PR 3 fixed-128 packing is kept as the
        # tile128 diagnostic so the two uplifts stay separable
        wb = min(w, L - 1)
        Wb = Wb_pad(wb)
        pad_len = round_up(2 * L + Wb + wb, 128)
        per_row = (2 * pad_len + 8 * Wb) * 4
        tile_i = pick_pair_tile(128, P, per_row, _VMEM_BUDGET)
        tile_b = pick_pair_tile(sched_pair_tile(P), P, per_row, _VMEM_BUDGET)
        R = row_block_policy(L)
        n_blocks = -(-(2 * L - 1) // R)
        qi = jnp.arange(P) % Q
        stripe = jnp.arange(P) // Q
        skipped = {"bound": 0.0, "index": 0.0, "bound128": 0.0}
        cells = {"bound": 0, "index": 0, "bound128": 0}
        for rnd in range(-(-N // M)):
            rank = jnp.minimum(rnd * M + stripe, N - 1)
            cidx = order[qi, rank]
            lbv = jnp.where(
                (rnd * M + stripe < N) & jnp.isfinite(slb[qi, rank]),
                slb[qi, rank], jnp.inf,
            )
            valid = jnp.isfinite(lbv)
            qrows, crows = q[qi], idx.series[cidx]
            # index schedule: stripe packing, live cutoff everywhere (PR 2)
            death = dtw_band_death_blocks(qrows, crows, w, kth[qi])
            nt = -(-P // tile_i)
            skipped["index"] += tile_skip_rate(death, n_blocks, tile_i) * nt
            cells["index"] += nt
            # bound schedule: ascending-bound packing, invalid slots poisoned
            perm = jnp.argsort(lbv)
            cut = jnp.where(valid, kth[qi], -jnp.inf)
            death = dtw_band_death_blocks(qrows[perm], crows[perm], w,
                                          cut[perm])
            nt = -(-P // tile_b)
            skipped["bound"] += tile_skip_rate(death, n_blocks, tile_b) * nt
            cells["bound"] += nt
            nt = -(-P // tile_i)
            skipped["bound128"] += (
                tile_skip_rate(death, n_blocks, tile_i) * nt)
            cells["bound128"] += nt
            # thread the k-th best forward (cutoff +infs cannot improve it)
            dd = ref.dtw_band_ref(qrows, crows, w, kth[qi])
            dd = jnp.where(valid, dd, jnp.inf)
            kth = jnp.minimum(kth, jnp.full((Q,), jnp.inf).at[qi].min(dd))
        for sched, tile in (("bound", tile_b), ("index", tile_i)):
            recs.append(dict(
                name=f"sched_{sched}_L{L}_w{w}_tile_skip_rate",
                us_per_call=skipped[sched] / cells[sched],
                derived=(f"skipped fraction of ({tile} pair-tile x "
                         f"{n_blocks} row-block) grid over the whole "
                         f"verification stream, P={P} per round"),
            ))
        recs.append(dict(
            name=f"sched_bound_L{L}_w{w}_tile128_skip_rate",
            us_per_call=skipped["bound128"] / cells["bound128"],
            derived=(f"bound packing at the PR 3 fixed {tile_i}-lane tile "
                     "(packing-only uplift, for the trajectory)"),
        ))
        recs.append(dict(
            name=f"sched_bound_L{L}_w{w}_round_tile_p",
            us_per_call=float(tile_b),
            derived="pair tile picked by tiling.sched_pair_tile for "
                    f"P={P} bound-ordered rounds",
        ))
    return recs


def _plan_records() -> list[dict]:
    """Self-tuning planner rows (see module docstring).

    The w in {26, 77} rows price serving on a serving-shaped store
    (L=256, N=192: each query has one true near neighbour, the rest of
    the corpus is background mass — the regime where a static budget
    over-provisions by 4x): calibrate once (host-side, outside the
    timing), then time the jitted *bound pass* (``run_plan``: every tier
    + compaction + seed verification — exactly the component the plan
    rewrite changes) under the static default plan vs the committed
    plan.  The engine's verification loop is bit-identical under the
    conservative profile (same bounds where they matter, per-query n_dtw
    equal — the ``_n_dtw`` rows and the planner property tests pin it),
    so folding its wall-clock into the ratio would only add its noise to
    an invariant term.  Both sides run with their *resolved* budgets
    (the adaptive bucket for the static plan) so the comparison is the
    plan rewrite, not a tracing artefact.  The w = 256 row runs the
    sched rows' exact workload and static-64 config, where the O(L)
    pairwise tier's realised mass collapses to zero and the planner
    drops it.
    """
    import dataclasses

    import jax

    from repro.data import make_dataset
    from repro.search import (
        CascadeConfig,
        EngineConfig,
        build_index,
        calibrate_plan,
        default_plan,
        nn_search,
    )
    from repro.search import planner as plr
    from repro.search.pipeline import resolve_adaptive_budget

    recs = []
    Q, L, M, k = _SCHED_Q, _SCHED_L, _SCHED_M, 1
    rng = np.random.default_rng(11)
    queries = rng.normal(size=(Q, L)).astype(np.float32)
    near = queries + 0.05 * rng.normal(size=(Q, L)).astype(np.float32)
    far = 5.0 + rng.normal(size=(176, L)).astype(np.float32)
    series = np.concatenate([near, far], axis=0)          # N = 192
    q = jnp.asarray(queries)
    for frac in _SCHED_W_FRACTIONS:
        w = max(1, int(round(frac * L)))
        idx = build_index(series, w)
        cascade = CascadeConfig(w=w, use_pallas=False)
        ecfg = EngineConfig(cascade=cascade, verify_chunk=M, k=k)
        # resolve the static plan's budget on host so the jitted baseline
        # runs the same plan the engine would commit to eagerly
        budget = resolve_adaptive_budget(q, idx, cascade, k, None)
        cascade_r = dataclasses.replace(cascade, survivor_budget=budget)
        ecfg_r = dataclasses.replace(ecfg, cascade=cascade_r)
        static_plan = default_plan(cascade_r)
        plr.plan_cache_clear()
        dec = calibrate_plan(q, idx, cascade_r, k, plan=static_plan)
        from repro.search import run_plan as _run_plan
        static_fn = jax.jit(
            lambda qq, _p=static_plan, _c=cascade_r: _run_plan(
                qq, idx, _c, _p, k=k).lb
        )
        auto_fn = jax.jit(
            lambda qq, _p=dec.plan, _c=cascade_r: _run_plan(
                qq, idx, _c, _p, k=k).lb
        )
        # ms-scale bound passes on a shared CPU drift with allocator/GC
        # phases, so the two sides are sampled *paired* (adjacent calls
        # see the same machine state) and the committed number is the
        # median of per-pair ratios — stable across runs where separate
        # medians swing by tens of percent
        import time as _time

        jax.block_until_ready(static_fn(q))
        jax.block_until_ready(auto_fn(q))
        ratios = []
        for _ in range(25):
            t0 = _time.perf_counter()
            jax.block_until_ready(static_fn(q))
            t_s = _time.perf_counter() - t0
            t0 = _time.perf_counter()
            jax.block_until_ready(auto_fn(q))
            ratios.append(t_s / (_time.perf_counter() - t0))
        speedup = float(np.median(ratios))
        res_auto = nn_search(idx, q, ecfg_r, plan=dec.plan)
        recs.append(dict(
            name=f"plan_auto_L256_w{w}_n_dtw",
            us_per_call=float(np.sum(np.array(res_auto.n_dtw))),
            derived="total verifications under the committed plan "
                    "(conservative profile: equals the static plan's)",
        ))
        recs.append(dict(
            name=f"plan_auto_L256_w{w}_tier_mass",
            us_per_call=float(np.sum(np.asarray(dec.stats.mass))),
            derived="total measured realised pruning mass over "
                    f"{int(dec.stats.pairs)} calibration pairs; "
                    f"decision: {dec.summary()}",
        ))
        recs.append(dict(
            name=f"plan_auto_L256_w{w}_speedup_vs_static",
            us_per_call=speedup,
            derived="median paired ratio: static-plan bound pass / "
                    "committed-plan bound pass (the component the rewrite "
                    "changes; engine verification is bit-identical, see "
                    f"the n_dtw rows) (budget {budget} -> {dec.budget}, "
                    f"dropped {list(dec.dropped)})",
        ))
    # w = L collapse: the sched rows' exact workload and static-64
    # config — the pairwise tier crosses nothing the cheap tiers did not
    # already prune
    w = L
    ds_w = make_dataset(n_classes=4, n_train_per_class=48,
                        n_test_per_class=4, length=L, seed=11)
    idx = build_index(ds_w.x_train, w, ds_w.y_train)
    cascade = CascadeConfig(w=w, use_pallas=False, survivor_budget=64)
    plr.plan_cache_clear()
    dec = calibrate_plan(jnp.asarray(ds_w.x_test[:Q]), idx, cascade, k)
    recs.append(dict(
        name=f"plan_auto_L256_w{w}_n_dropped",
        us_per_call=float(len(dec.dropped)),
        derived=f"tiers dropped at w=L: {list(dec.dropped)} "
                "(bands-tier refinement mass collapses; guarded >= 1)",
    ))
    plr.plan_cache_clear()
    return recs


def _sketch_records() -> list[dict]:
    """Quantised sketch tier + store-mask rows (see module docstring).

    The store is *Kim-blind* by construction: every series (queries
    included) shares its first/last four values and carries the same
    interior +/-12 extrema spikes, so the O(1) Kim tier's boundary and
    max/min terms are identically zero and the planner drops it on both
    sides.  The separating signal is a constant +5 offset on the
    background mass — exactly what a segment-mean sketch sees — so the
    sketchless committed plan prunes with the O(L) bands tier and the
    sketch committed plan prunes the *same* pairs with the O(S) int8
    tier (kim/bands measure zero incremental mass behind it and are
    dropped).  Both sides are planner-committed under the same resolved
    budget, so the ratio prices the tier capability, not the plan
    machinery.  Paired sampling as in the planner rows.
    """
    import dataclasses
    import time as _time

    import jax

    from repro.search import (
        CascadeConfig,
        EngineConfig,
        build_index,
        calibrate_plan,
        run_plan,
    )
    from repro.search import planner as plr
    from repro.search.pipeline import resolve_adaptive_budget

    recs = []
    Q, L, k = _SCHED_Q, _SCHED_L, 1
    rng = np.random.default_rng(11)
    queries = 0.1 * rng.normal(size=(Q, L)).astype(np.float32)
    near = queries + 0.05 * rng.normal(size=(Q, L)).astype(np.float32)
    far = 5.0 + 0.1 * rng.normal(size=(176, L)).astype(np.float32)

    def _kim_blind(x):
        x = np.array(x, np.float32, copy=True)
        edge = np.linspace(0.0, 0.3, 4, dtype=np.float32)
        x[:, :4] = edge
        x[:, -4:] = edge[::-1]
        x[:, 10] = 12.0                       # shared global max
        x[:, 20] = -12.0                      # shared global min
        return x

    queries, near, far = map(_kim_blind, (queries, near, far))
    series = np.concatenate([near, far], axis=0)          # N = 192
    q = jnp.asarray(queries)
    for frac in _SCHED_W_FRACTIONS:
        w = max(1, int(round(frac * L)))
        idx = build_index(series, w)
        c_ns = CascadeConfig(w=w, use_pallas=False)
        c_sk = CascadeConfig(w=w, use_pallas=False, use_sketch=True)
        budget = resolve_adaptive_budget(q, idx, c_ns, k, None)
        c_ns = dataclasses.replace(c_ns, survivor_budget=budget)
        c_sk = dataclasses.replace(c_sk, survivor_budget=budget)
        plr.plan_cache_clear()
        dec_ns = calibrate_plan(q, idx, c_ns, k)
        dec_sk = calibrate_plan(q, idx, c_sk, k)
        ns_fn = jax.jit(
            lambda qq, _p=dec_ns.plan, _c=c_ns: run_plan(
                qq, idx, _c, _p, k=k).lb
        )
        sk_fn = jax.jit(
            lambda qq, _p=dec_sk.plan, _c=c_sk: run_plan(
                qq, idx, _c, _p, k=k).lb
        )
        jax.block_until_ready(ns_fn(q))
        jax.block_until_ready(sk_fn(q))
        ratios = []
        for _ in range(25):
            t0 = _time.perf_counter()
            jax.block_until_ready(ns_fn(q))
            t_n = _time.perf_counter() - t0
            t0 = _time.perf_counter()
            jax.block_until_ready(sk_fn(q))
            ratios.append(t_n / (_time.perf_counter() - t0))
        names = list(dec_sk.stats.names)
        s_mass = float(np.asarray(dec_sk.stats.mass)[names.index("sketch")])
        bpc = float(idx.sk_lo.nbytes + idx.sk_hi.nbytes) / idx.n
        recs.append(dict(
            name=f"sketch_L256_w{w}_tier_mass",
            us_per_call=s_mass,
            derived="sketch-tier realised pruning mass over "
                    f"{int(dec_sk.stats.pairs)} calibration pairs; "
                    f"decision: {dec_sk.summary()}",
        ))
        recs.append(dict(
            name=f"sketch_L256_w{w}_bytes_per_cand",
            us_per_call=bpc,
            derived="int8 sketch store bytes per candidate (sk_lo + "
                    "sk_hi; the f32 scale is store-wide); acceptance "
                    "budget <= 32, guarded in ci.yml",
        ))
        recs.append(dict(
            name=f"sketch_L256_w{w}_speedup_vs_nosketch",
            us_per_call=float(np.median(ratios)),
            derived="median paired ratio: committed sketchless bound "
                    "pass / committed use_sketch bound pass on the "
                    "Kim-blind store (sketchless plan "
                    f"{list(dec_ns.order)}, sketch plan "
                    f"{list(dec_sk.order)}); CI floor 0.95",
        ))
    # --- store-level candidate masking: planted dead mass is retired ---
    # outlier rows sit *off* the N=128 calibration stride
    # (planner.calibration_sample picks [0, 18, ..., 127]), so no
    # calibration query keeps them: provably dead under any tau
    rng2 = np.random.default_rng(5)
    walks = np.cumsum(
        rng2.normal(size=(128, 64)).astype(np.float32), axis=1
    )
    out_rows = np.array([5, 40, 70, 100])
    walks[out_rows] += 50.0
    mcfg = EngineConfig(
        cascade=CascadeConfig(w=12, use_pallas=False, use_sketch=True),
        k=2,
    )
    plr.plan_cache_clear()
    midx = build_index(walks, 12, calibrate=mcfg, mask=True)
    live = np.asarray(midx.live)
    recs.append(dict(
        name="mask_dense_skip_frac",
        us_per_call=float(1.0 - live.mean()),
        derived=f"store fraction retired by the LOO sketch mask "
                f"({int((~live).sum())}/128 dead; all {len(out_rows)} "
                f"planted outliers dead: {bool(not live[out_rows].any())});"
                " CI requires > 0",
    ))
    plr.plan_cache_clear()
    return recs


def _guard_records() -> list[dict]:
    """Price the default-on exactness guards (search/guards.py).

    ``guard_overhead_L256_w{26,77}_frac`` is the fractional wall-clock
    cost of the guard ops on the jitted *bound pass* (``run_plan``:
    tiers + compaction + seed verification — where the finite gates,
    conservation distinct-count and admissibility spot-check live), on
    the planner rows' serving-shaped workload (L=256, N=192, Q=16).
    Sampled paired like the planner rows, committed as the median of
    per-pair ``t_on / t_off - 1``.  The guarded side returns the guard
    vector alongside the bounds so XLA cannot dead-code-eliminate the
    checks.  CI fails if any ``guard_overhead_*_frac`` exceeds 0.05 —
    the guards stay default-on only while they are effectively free.
    """
    import time as _time

    import jax

    from repro.search import (
        CascadeConfig,
        GuardConfig,
        build_index,
        default_plan,
        run_plan,
    )

    recs = []
    Q, L = _SCHED_Q, _SCHED_L
    k = 1
    rng = np.random.default_rng(11)
    queries = rng.normal(size=(Q, L)).astype(np.float32)
    near = queries + 0.05 * rng.normal(size=(Q, L)).astype(np.float32)
    far = 5.0 + rng.normal(size=(176, L)).astype(np.float32)
    series = np.concatenate([near, far], axis=0)          # N = 192
    q = jnp.asarray(queries)
    g_on = GuardConfig()
    g_off = GuardConfig(enabled=False)
    for frac in _SCHED_W_FRACTIONS:
        w = max(1, int(round(frac * L)))
        idx = build_index(series, w)
        cascade = CascadeConfig(w=w, use_pallas=False)
        plan = default_plan(cascade)

        def run_off(qq, _c=cascade, _p=plan):
            return run_plan(qq, idx, _c, _p, k=k, guards=g_off).lb

        def run_on(qq, _c=cascade, _p=plan):
            r = run_plan(qq, idx, _c, _p, k=k, guards=g_on)
            return r.lb, r.guard.to_vector()

        off_fn = jax.jit(run_off)
        on_fn = jax.jit(run_on)
        jax.block_until_ready(off_fn(q))
        jax.block_until_ready(on_fn(q))
        # alternate which side runs first within each pair: with on/off
        # always in the same order the first call absorbs the allocator
        # warm-up of the pair and the ratio carries a systematic bias
        ratios = []
        for it in range(50):
            first, second = (on_fn, off_fn) if it % 2 == 0 \
                else (off_fn, on_fn)
            t0 = _time.perf_counter()
            jax.block_until_ready(first(q))
            t_a = _time.perf_counter() - t0
            t0 = _time.perf_counter()
            jax.block_until_ready(second(q))
            t_b = _time.perf_counter() - t0
            t_on, t_off = (t_a, t_b) if it % 2 == 0 else (t_b, t_a)
            ratios.append(t_on / t_off - 1.0)
        recs.append(dict(
            name=f"guard_overhead_L256_w{w}_frac",
            us_per_call=float(np.median(ratios)),
            derived="median paired fractional overhead of default-on "
                    "guards on the jitted bound pass (t_on/t_off - 1; "
                    "CI bound 0.05)",
        ))
    return recs


def kernel_records() -> list[dict]:
    """Each record: {name, us_per_call, derived} (derived is a string)."""
    recs = []
    Q, C, L, w, v = 16, 256, 128, 38, 4
    q, c = random_pairs(max(Q, C), L, seed=1)
    qj = jnp.asarray(q[:Q])
    cj = jnp.asarray(c[:C])
    u, lo = envelope_op(cj, w)

    sec = time_fn(lambda b: ref.envelope_ref(b, w), cj)
    recs.append(dict(
        name=f"envelope_jnp_{C}x{L}", us_per_call=1e6 * sec / C,
        derived=f"bytes_per_series={L * 4 * 3}",
    ))
    sec = time_fn(lambda a, b, e1, e2: ref.lb_keogh_ref(a, e1, e2), qj, cj, u, lo)
    recs.append(dict(
        name=f"lb_keogh_jnp_{Q}x{C}x{L}", us_per_call=1e6 * sec / (Q * C),
        derived=f"flops_per_pair={4 * L}",
    ))
    sec = time_fn(
        lambda a, b, e1, e2: ref.lb_enhanced_ref(a, b, e1, e2, w, v),
        qj, cj, u, lo,
    )
    recs.append(dict(
        name=f"lb_enhanced4_jnp_{Q}x{C}x{L}", us_per_call=1e6 * sec / (Q * C),
        derived=f"flops_per_pair={4 * L + 4 * v * v}",
    ))
    P = 64
    a2, b2 = random_pairs(P, L, seed=2)
    sec = time_fn(lambda x, y: ref.dtw_band_ref(x, y, w), jnp.asarray(a2), jnp.asarray(b2))
    recs.append(dict(
        name=f"dtw_band_jnp_{P}x{L}", us_per_call=1e6 * sec / P,
        derived=f"flops_per_pair={10 * L * min(2 * w + 1, L)}",
    ))

    # band-packed O(L*W) scaling: per-call time should grow ~linearly in w
    Ls, Ps = _DTW_SCALING_L, _DTW_SCALING_P
    a3, b3 = random_pairs(Ps, Ls, seed=3)
    a3j, b3j = jnp.asarray(a3), jnp.asarray(b3)
    for frac in _DTW_W_FRACTIONS:
        ws = min(Ls, max(1, int(round(frac * Ls))))
        sec = time_fn(lambda x, y, _w=ws: ref.dtw_band_ref(x, y, _w), a3j, b3j)
        recs.append(dict(
            name=f"dtw_band_jnp_L{Ls}_w{ws}", us_per_call=1e6 * sec / Ps,
            derived=f"flops_per_pair={10 * Ls * min(2 * ws + 1, Ls)}",
        ))

    # --- pairwise survivor hot path: PR 1 vmapped jnp vs Pallas kernel ---
    # both sides jitted: PR 1 ran the vmapped math inside jitted
    # staged_bounds, so an eager-ref timing would just measure dispatch
    Pp, Lp, wp, vp = 128, 256, 26, 4
    qp, cp = random_pairs(Pp, Lp, seed=4)
    qpj, cpj = jnp.asarray(qp), jnp.asarray(cp)
    up, lop = envelope_op(cpj, wp)
    jit_pairwise_ref = jax.jit(
        lambda a, b, e1, e2: ref.lb_enhanced_pairwise_ref(a, b, e1, e2, wp, vp)
    )
    # sub-ms calls: the jnp/pallas ratio is the satellite metric, so give
    # it enough repeats that the median is signal
    sec_jnp = time_fn(jit_pairwise_ref, qpj, cpj, up, lop, repeats=25)
    recs.append(dict(
        name=f"lb_enhanced_pairwise_jnp_{Pp}x{Lp}",
        us_per_call=1e6 * sec_jnp / Pp,
        derived=f"flops_per_pair={4 * Lp + 4 * vp * vp}",
    ))
    sec_pal = time_fn(
        lambda a, b, e1, e2: lb_enhanced_pairwise_op(a, b, e1, e2, wp, vp),
        qpj, cpj, up, lop, repeats=25,
    )
    recs.append(dict(
        name=f"lb_enhanced_pairwise_pallas_{Pp}x{Lp}",
        us_per_call=1e6 * sec_pal / Pp,
        derived="interpret-mode semantics timing on CPU",
    ))
    recs.append(dict(
        name=f"lb_enhanced_pairwise_{Pp}x{Lp}_speedup_vs_pr1",
        us_per_call=sec_jnp / sec_pal,
        derived="ratio: PR1 vmapped-jnp tier-2 / pairwise Pallas kernel",
    ))

    # --- early-exit dtw_band: PR 1 per-step poisoning vs row-block grid ---
    Le, Pe = _DTW_EE_L, _DTW_EE_P
    a4, b4 = random_pairs(Pe, Le, seed=5)
    a4j, b4j = jnp.asarray(a4), jnp.asarray(b4)
    for frac in _DTW_W_FRACTIONS:
        we = min(Le, max(1, int(round(frac * Le))))
        d_true = dtw_band_op(a4j, b4j, we)
        # aggressive cutoff: every lane abandons inside the first row block,
        # so the ee path's remaining blocks are genuinely skipped
        cut = jnp.asarray(d_true) * 0.01
        times = {}
        for tag, ee in (("pr1", False), ("ee", True)):
            for ctag, c in (("nocut", None), ("cut", cut)):
                sec = time_fn(
                    lambda x, y, _w=we, _c=c, _ee=ee: dtw_band_op(
                        x, y, _w, _c, early_exit=_ee
                    ),
                    a4j, b4j,
                )
                times[(tag, ctag)] = sec
                recs.append(dict(
                    name=f"dtw_band_{tag}_L{Le}_w{we}_{ctag}",
                    us_per_call=1e6 * sec / Pe,
                    derived=f"flops_per_pair={10 * Le * min(2 * we + 1, Le)}",
                ))
        for ctag in ("nocut", "cut"):
            recs.append(dict(
                name=f"dtw_band_ee_L{Le}_w{we}_{ctag}_speedup_vs_pr1",
                us_per_call=times[("pr1", ctag)] / times[("ee", ctag)],
                derived="ratio: PR1 lane-poisoning sweep / row-block early exit",
            ))

    # --- streaming DMA pipeline across the old length ceiling -------------
    recs.extend(_stream_records())

    # --- scheduler observability: bound-ordered vs stripe packing ---------
    recs.extend(_sched_records())

    # --- self-tuning planner: measured mass/cost plan commits -------------
    recs.extend(_plan_records())

    # --- quantised sketch tier + store-level candidate masking ------------
    recs.extend(_sketch_records())

    # --- exactness guards: fractional overhead on the bound pass ----------
    recs.extend(_guard_records())
    return recs


def write_json(recs: list[dict], path: str = _JSON_PATH) -> None:
    with open(path, "w") as f:
        json.dump(
            {r["name"]: round(r["us_per_call"], 3) for r in recs},
            f, indent=2, sort_keys=True,
        )
        f.write("\n")


def kernel_rows() -> list[str]:
    recs = kernel_records()
    write_json(recs)
    fmt = {
        "envelope_jnp": "{:.2f}", "lb_keogh_jnp": "{:.3f}",
        "lb_enhanced4_jnp": "{:.3f}",
    }
    rows = []
    for r in recs:
        prec = next(
            (f for k, f in fmt.items() if r["name"].startswith(k)), "{:.1f}"
        )
        us = prec.format(r["us_per_call"])
        rows.append(f"{r['name']},{us},{r['derived']}")
    return rows
