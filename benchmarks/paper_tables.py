"""Paper-table benchmarks (Tables I-III + Fig. 1 analogs) on synthetic
UCR-like datasets.

Each function returns a list of CSV rows ``name,us_per_call,derived`` where
``derived`` carries the table's actual quantity (tightness / pruning power
/ rank / classification time ratio).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BOUNDS,
    bound_matrix,
    dtw_matrix,
    simulate_sequential_pruning,
    time_fn,
)
from repro.data import make_dataset, random_pairs
from repro.search import CascadeConfig, EngineConfig, build_index, nn_search

WINDOW_FRACTIONS = (0.1, 0.3, 0.6, 1.0)


def _datasets(n=3, L=96):
    return [
        make_dataset(n_classes=3, n_train_per_class=15, n_test_per_class=5,
                     length=L, seed=s)
        for s in range(n)
    ]


def table1_tightness() -> list[str]:
    """Table I analog: mean tightness LB/DTW per bound per window."""
    rows = []
    datasets = _datasets()
    for frac in WINDOW_FRACTIONS:
        per_bound: dict[str, list[float]] = {b: [] for b in BOUNDS}
        for ds in datasets:
            w = max(1, int(frac * ds.length))
            d = np.array(dtw_matrix(ds.x_test, ds.x_train, w))
            for b in BOUNDS:
                t0 = time.perf_counter()
                lb = np.array(bound_matrix(b, ds.x_test, ds.x_train, w))
                dt = time.perf_counter() - t0
                tight = np.mean(lb / np.maximum(d, 1e-9))
                per_bound[b].append((tight, dt, lb.size))
        # mean tightness + rank per bound at this window
        means = {b: np.mean([x[0] for x in per_bound[b]]) for b in BOUNDS}
        order = sorted(means, key=means.get, reverse=True)
        for b in BOUNDS:
            us = 1e6 * np.sum([x[1] for x in per_bound[b]]) / np.sum(
                [x[2] for x in per_bound[b]]
            )
            rank = order.index(b) + 1
            rows.append(
                f"tightness_w{frac:.1f}_{b},{us:.3f},"
                f"tightness={means[b]:.4f};rank={rank}"
            )
    return rows


def table2_pruning_power() -> list[str]:
    """Table II analog: paper-semantics sequential pruning power."""
    rows = []
    datasets = _datasets()
    for frac in WINDOW_FRACTIONS:
        for b in BOUNDS:
            ps = []
            for ds in datasets:
                w = max(1, int(frac * ds.length))
                d = np.array(dtw_matrix(ds.x_test, ds.x_train, w))
                lb = np.array(bound_matrix(b, ds.x_test, ds.x_train, w))
                ps.append(simulate_sequential_pruning(lb, d))
            rows.append(
                f"pruning_w{frac:.1f}_{b},0.0,P={np.mean(ps):.4f}"
            )
    return rows


def table3_nn_time() -> list[str]:
    """Table III analog: engine NN-DTW wall time per bound config.

    The engine's cascade always includes the O(1) Kim tier; the O(L) tier is
    the named bound (ENHANCED^0 == KEOGH bridge only)."""
    rows = []
    ds = _datasets(n=1, L=96)[0]
    for frac in WINDOW_FRACTIONS:
        w = max(1, int(frac * ds.length))
        for v in (0, 1, 2, 4):           # v=0 -> pure Keogh bridge
            idx = build_index(ds.x_train, w, ds.y_train)
            cfg = EngineConfig(
                cascade=CascadeConfig(w=w, v=v), verify_chunk=8, k=1
            )
            fn = lambda q: nn_search(idx, q, cfg).dists
            sec = time_fn(fn, jnp.asarray(ds.x_test))
            res = nn_search(idx, ds.x_test, cfg)
            p = float(np.mean(np.array(res.pruning_power())))
            name = "lb_keogh" if v == 0 else f"lb_enhanced_{v}"
            us = 1e6 * sec / ds.x_test.shape[0]
            rows.append(
                f"nn_time_w{frac:.1f}_{name},{us:.1f},P={p:.4f}"
            )
    return rows


def fig1_tightness_vs_time() -> list[str]:
    """Fig. 1 analog: tightness vs per-pair compute time, random pairs,
    L=256, W=0.3L (the paper's protocol, reduced pair count for CPU)."""
    rows = []
    L = 256
    a, b = random_pairs(64, L, seed=0)
    w = int(0.3 * L)
    d = None
    for bound in BOUNDS:
        fn = jax.jit(lambda q, c: bound_matrix(bound, q, c, w))
        # per-pair timing over the 64x64 matrix
        sec = time_fn(fn, jnp.asarray(a), jnp.asarray(b))
        lb = np.array(fn(jnp.asarray(a), jnp.asarray(b)))
        if d is None:
            d = np.array(dtw_matrix(a, b, w))
        tight = float(np.mean(lb / np.maximum(d, 1e-9)))
        us = 1e6 * sec / lb.size
        rows.append(f"fig1_{bound},{us:.3f},tightness={tight:.4f}")
    return rows
