"""Shared benchmark utilities: timing, bound registry, datasets."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import envelope, lb_enhanced_matrix, lb_keogh_matrix
from repro.core.dtw import dtw_pairs
from repro.core.lower_bounds import (
    lb_improved,
    lb_kim,
    lb_new,
)
from repro.search.cascade import lb_kim_tier
from repro.search.index import build_index


def time_fn(fn: Callable, *args, repeats: int = 3) -> float:
    """Median wall seconds for jitted fn (post-warmup)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bound_matrix(name: str, q, c, w: int):
    """(Q, C) matrix of the named bound (vectorised paths where available)."""
    qj, cj = jnp.asarray(q), jnp.asarray(c)
    if name == "lb_kim":
        idx = build_index(cj, w)
        return lb_kim_tier(qj, idx)
    if name == "lb_keogh":
        u, lo = envelope(cj, w)
        return lb_keogh_matrix(qj, u, lo)
    if name.startswith("lb_enhanced"):
        v = int(name.rsplit("_", 1)[-1])
        u, lo = envelope(cj, w)
        return lb_enhanced_matrix(qj, cj, u, lo, w, v)
    if name == "lb_improved":
        f = jax.vmap(jax.vmap(lb_improved, (None, 0, None)), (0, None, None))
        return f(qj, cj, w)
    if name == "lb_new":
        f = jax.vmap(jax.vmap(lb_new, (None, 0, None)), (0, None, None))
        return f(qj, cj, w)
    raise ValueError(name)


BOUNDS = (
    "lb_kim",
    "lb_keogh",
    "lb_improved",
    "lb_new",
    "lb_enhanced_1",
    "lb_enhanced_2",
    "lb_enhanced_3",
    "lb_enhanced_4",
)


def dtw_matrix(q, c, w: int):
    return dtw_pairs(jnp.asarray(q), jnp.asarray(c), w)


def simulate_sequential_pruning(
    lb: np.ndarray, d: np.ndarray, order: np.ndarray | None = None
) -> float:
    """The paper's NN-DTW loop semantics (SS IV-A): walk candidates in
    order, skip when LB >= best-so-far.  Returns mean pruning power P."""
    T, N = lb.shape
    if order is None:
        order = np.arange(N)
    skipped = 0
    for t in range(T):
        best = np.inf
        for j in order:
            if lb[t, j] >= best:
                skipped += 1
            else:
                best = min(best, d[t, j])
    return skipped / (T * N)
