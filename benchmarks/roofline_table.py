"""Roofline table assembly from results/dryrun JSONs (SSRoofline)."""

from __future__ import annotations

import glob
import json
import os


def roofline_rows(result_dir: str = "results/dryrun") -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(result_dir, "single__*.json"))):
        r = json.load(open(path))
        cell = f"{r['arch']}__{r['shape']}"
        if r.get("status") == "skip":
            rows.append(f"roofline_{cell},0.0,SKIP:{r['reason'][:60]}")
            continue
        rf = r.get("roofline")
        if not rf:
            continue
        rows.append(
            f"roofline_{cell},{rf['bound_step_s'] * 1e6:.0f},"
            f"dominant={rf['dominant']};"
            f"compute_s={rf['compute_s']:.3g};"
            f"memory_s={rf['memory_s']:.3g};"
            f"collective_s={rf['collective_s']:.3g};"
            f"frac={rf['roofline_fraction']:.4f}"
        )
    return rows
