"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * Table I analog  — tightness per bound per window
  * Table II analog — sequential pruning power (paper semantics)
  * Table III analog— NN-DTW classification time with the engine
  * Fig. 1 analog   — tightness vs per-pair time, L=256, W=0.3L
  * kernel micro-benchmarks (pure-jnp refs; interpret kernels are
    semantics-only on CPU)
  * the roofline table from the dry-run artifacts (if present)

Run: ``PYTHONPATH=src python -m benchmarks.run [--fast]``
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower table benchmarks")
    ap.add_argument("--skip", default="", help="comma-list of sections")
    args = ap.parse_args()
    skip = set(filter(None, args.skip.split(",")))

    from benchmarks import kernel_bench, paper_tables, roofline_table

    sections = [
        ("fig1", paper_tables.fig1_tightness_vs_time),
        ("kernels", kernel_bench.kernel_rows),
        ("table1", paper_tables.table1_tightness),
        ("table2", paper_tables.table2_pruning_power),
        ("table3", paper_tables.table3_nn_time),
        ("roofline", roofline_table.roofline_rows),
    ]
    if args.fast:
        sections = [s for s in sections if s[0] in ("fig1", "kernels", "roofline")]

    print("name,us_per_call,derived")
    for name, fn in sections:
        if name in skip:
            continue
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
