"""Lower-bound correctness: oracle equivalence + the paper's invariants.

The central property (Theorems 1-2): every bound is <= DTW_w for every
random (A, B, w, V).  Plus the paper's tightness claims: LB_ENHANCED^V is
tighter than LB_KEOGH and monotone non-decreasing in V.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    dtw,
    envelope,
    lb_enhanced,
    lb_enhanced_bands,
    lb_enhanced_matrix,
    lb_improved,
    lb_keogh,
    lb_keogh_matrix,
    lb_kim,
    lb_kim_paper,
    lb_new,
    lb_yi,
    oracle,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _pair(seed, L):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=L).astype(np.float32),
            rng.normal(size=L).astype(np.float32))


@pytest.mark.parametrize("L,w,v", [(16, 4, 2), (32, 8, 4), (33, 33, 4), (20, 0, 4), (24, 5, 0)])
def test_oracle_equivalence(L, w, v):
    a, b = _pair(1, L)
    ja, jb = jnp.array(a), jnp.array(b)
    assert np.allclose(float(lb_keogh(ja, jb, w)), oracle.lb_keogh(a, b, w), rtol=1e-4, atol=1e-5)
    assert np.allclose(float(lb_improved(ja, jb, w)), oracle.lb_improved(a, b, w), rtol=1e-4, atol=1e-5)
    assert np.allclose(float(lb_new(ja, jb, w)), oracle.lb_new(a, b, w), rtol=1e-4, atol=1e-5)
    assert np.allclose(float(lb_yi(ja, jb)), oracle.lb_yi(a, b), rtol=1e-4, atol=1e-5)
    assert np.allclose(float(lb_enhanced(ja, jb, w, v)), oracle.lb_enhanced(a, b, w, v), rtol=1e-4, atol=1e-5)
    assert np.allclose(float(lb_enhanced_bands(ja, jb, w, v)), oracle.lb_enhanced_bands(a, b, w, v), rtol=1e-4, atol=1e-5)


@given(
    L=st.integers(4, 40),
    w=st.integers(0, 40),
    v=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_all_bounds_below_dtw(L, w, v, seed):
    """Theorem 1/2 invariant: LB(A,B) <= DTW_w(A,B), always."""
    a, b = _pair(seed, L)
    ja, jb = jnp.array(a), jnp.array(b)
    d = float(dtw(ja, jb, w)) * (1 + 1e-4) + 1e-5
    assert float(lb_kim(ja, jb)) <= d
    assert float(lb_yi(ja, jb)) <= d
    assert float(lb_keogh(ja, jb, w)) <= d
    assert float(lb_improved(ja, jb, w)) <= d
    assert float(lb_new(ja, jb, w)) <= d
    assert float(lb_enhanced_bands(ja, jb, w, v)) <= d
    assert float(lb_enhanced(ja, jb, w, v)) <= d


def test_enhanced_tighter_than_keogh_in_aggregate():
    """Paper SS III/Fig. 1: LB_ENHANCED^V is tighter than LB_KEOGH *on
    average* (the paper's claim is aggregate — per-pair a band minimum can
    undercut the Keogh column it replaces, see the counterexample test)."""
    rng = np.random.default_rng(0)
    L, w, n = 64, 16, 200
    a = rng.normal(size=(n, L)).astype(np.float32).cumsum(1)
    b = rng.normal(size=(n, L)).astype(np.float32).cumsum(1)
    a = (a - a.mean(1, keepdims=True)) / (a.std(1, keepdims=True) + 1e-9)
    b = (b - b.mean(1, keepdims=True)) / (b.std(1, keepdims=True) + 1e-9)
    keogh = np.array([float(lb_keogh(jnp.array(x), jnp.array(y), w))
                      for x, y in zip(a, b)])
    prev = keogh
    for v in (1, 2, 3, 4):
        enh = np.array([float(lb_enhanced(jnp.array(x), jnp.array(y), w, v))
                        for x, y in zip(a, b)])
        assert enh.mean() >= prev.mean() * (1 - 1e-4), (v, enh.mean(), prev.mean())
        prev = enh
    assert prev.mean() > keogh.mean()     # V=4 strictly tighter on average


def test_enhanced_not_pointwise_dominant():
    """Documented finding: there exist pairs where LB_ENHANCED^V <
    LB_KEOGH — an elastic band's minimum can be smaller than the Keogh
    column term it replaces (e.g. an early query point that matches the
    candidate's *later* band cells).  Hence aggregate-only claims above."""
    rng = np.random.default_rng(0)
    hits = 0
    for seed in range(200):
        a, b = _pair(seed, 12)
        ja, jb = jnp.array(a), jnp.array(b)
        if float(lb_enhanced(ja, jb, 4, 4)) < float(lb_keogh(ja, jb, 4)) - 1e-6:
            hits += 1
    assert hits > 0, "expected at least one non-dominant pair"


def test_w0_bounds_equal_euclidean():
    """At W=0 the envelope bounds equal the squared Euclidean distance
    (= DTW_0), the paper's Table I row-one observation."""
    a, b = _pair(7, 32)
    ja, jb = jnp.array(a), jnp.array(b)
    ed = float(np.sum((a - b) ** 2))
    assert np.allclose(float(lb_keogh(ja, jb, 0)), ed, rtol=1e-4)
    assert np.allclose(float(lb_enhanced(ja, jb, 0, 4)), ed, rtol=1e-4)


@given(L=st.integers(3, 16), seed=st.integers(0, 2**31 - 1))
def test_kim_paper_variant_soundness(L, seed):
    """The paper's LB_KIM sum-of-features variant: we could not prove it
    sound, but adversarial search (40k random pairs + exhaustive small
    value grids) found no violation — this property test keeps watching.
    The engine still uses the provably-safe ``lb_kim`` (max, not sum)."""
    a, b = _pair(seed, L)
    ja, jb = jnp.array(a), jnp.array(b)
    d = oracle.dtw(a, b, None)
    paper = float(lb_kim_paper(ja, jb))
    safe = float(lb_kim(ja, jb))
    assert safe <= d * (1 + 1e-4) + 1e-5
    assert paper <= d * (1 + 1e-4) + 1e-5
    # (safe vs paper are incomparable: safe needs only the *witness* series'
    # extremum interior; paper needs both series' — either can be tighter)


def test_matrix_variants_match_pairwise(rng):
    q = rng.normal(size=(4, 24)).astype(np.float32)
    c = rng.normal(size=(6, 24)).astype(np.float32)
    u, lo = envelope(jnp.array(c), 5)
    km = np.array(lb_keogh_matrix(jnp.array(q), u, lo))
    em = np.array(lb_enhanced_matrix(jnp.array(q), jnp.array(c), u, lo, 5, 3))
    for i in range(4):
        for j in range(6):
            assert np.allclose(km[i, j], oracle.lb_keogh(q[i], c[j], 5), rtol=1e-4, atol=1e-5)
            assert np.allclose(em[i, j], oracle.lb_enhanced(q[i], c[j], 5, 3), rtol=1e-4, atol=1e-5)
