"""Search-engine invariants: pruning must never change the result."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import make_dataset
from repro.search import (
    CascadeConfig,
    EngineConfig,
    brute_force,
    build_index,
    classify,
    compute_bounds,
    nn_search,
)

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _setup(w=8, n_per=12, L=48, seed=0, k=1, chunk=16, verify=4):
    ds = make_dataset(n_classes=3, n_train_per_class=n_per,
                      n_test_per_class=4, length=L, seed=seed)
    idx = build_index(ds.x_train, w, ds.y_train)
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, candidate_chunk=chunk),
        verify_chunk=verify, k=k,
    )
    return ds, idx, cfg


def test_engine_exact_vs_brute_force():
    ds, idx, cfg = _setup()
    res = nn_search(idx, ds.x_test, cfg)
    bd, _ = brute_force(idx, ds.x_test, cfg.cascade.w, k=1)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd), rtol=1e-4)


@given(
    w=st.integers(0, 24),
    k=st.integers(1, 3),
    verify=st.integers(1, 9),
    seed=st.integers(0, 1000),
)
def test_engine_exactness_property(w, k, verify, seed):
    """Exactness certificate holds for every (w, k, chunking, data)."""
    ds, idx, cfg = _setup(w=w, seed=seed, k=k, verify=verify)
    res = nn_search(idx, ds.x_test, cfg)
    bd, _ = brute_force(idx, ds.x_test, w, k=k)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-4, atol=1e-5)


def test_pruning_power_positive():
    ds, idx, cfg = _setup(w=4)
    res = nn_search(idx, ds.x_test, cfg)
    p = float(np.mean(np.array(res.pruning_power())))
    assert 0.0 < p < 1.0
    assert np.all(np.array(res.n_dtw) >= 1)


def test_bounds_below_true_distance():
    ds, idx, cfg = _setup()
    lb = np.array(compute_bounds(jnp.asarray(ds.x_test), idx, cfg.cascade))
    from repro.search.engine import brute_force as bf
    d, _ = bf(idx, ds.x_test, cfg.cascade.w, k=idx.n)
    # compare the full distance matrix against bounds (sorted idx mismatch
    # is fine: compare against per-pair DTW via the engine's lb invariant)
    from repro.core import dtw_pairs
    dm = np.array(dtw_pairs(jnp.asarray(ds.x_test), idx.series, cfg.cascade.w))
    assert np.all(lb <= dm * (1 + 1e-4) + 1e-4)


def test_classification_beats_chance():
    ds, idx, cfg = _setup(w=8, n_per=20)
    pred, _ = classify(idx, ds.x_test, cfg)
    acc = float(np.mean(np.array(pred) == ds.y_test))
    assert acc > 0.5           # 3 classes -> chance is 0.33


def test_exclude_self():
    ds, idx, cfg = _setup()
    q = ds.x_train[:6]
    res = nn_search(idx, q, cfg, exclude=jnp.arange(6))
    assert np.all(np.array(res.idx[:, 0]) != np.arange(6))
    res2 = nn_search(idx, q, cfg)
    assert np.all(np.array(res2.idx[:, 0]) == np.arange(6))   # self is NN
    assert np.allclose(np.array(res2.dists[:, 0]), 0.0, atol=1e-5)
