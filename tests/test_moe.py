"""MoE routing correctness: the shard_map EP path vs a dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_apply, moe_init


def dense_moe_reference(p, x, top_k, n_real, act="silu"):
    """Every expert computes every token; outputs combined by the same
    renormalised top-k gates.  No capacity limit — ground truth when the
    EP path has capacity_factor high enough for zero drops."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    E = logits.shape[1]
    if n_real < E:
        logits = jnp.where(jnp.arange(E)[None] >= n_real, -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.sum(w, -1, keepdims=True)
    y = jnp.zeros_like(xt)
    for e in range(n_real):
        h = xt @ p["wi"][e]
        g = jax.nn.silu(xt @ p["wg"][e])
        out_e = (g * h) @ p["wo"][e]
        gate = jnp.sum(jnp.where(ids == e, w, 0.0), axis=-1)
        y = y + out_e * gate[:, None].astype(xt.dtype)
    return y.reshape(B, S, d)


@pytest.mark.parametrize("E,k", [(4, 2), (8, 2), (8, 6)])
def test_ep_matches_dense_reference(rng, E, k):
    d, f, B, S = 16, 32, 2, 8
    p = moe_init(jax.random.PRNGKey(0), d, f, E, 0, "silu")
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    y, aux = moe_apply(
        p, x, top_k=k, n_real=E, act="silu", mesh=None,
        capacity_factor=float(E),      # no drops
    )
    want = dense_moe_reference(p, x, k, E)
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=2e-3, atol=2e-3)
    assert bool(jnp.isfinite(aux))


def test_expert_padding_gets_no_tokens(rng):
    """Padded experts (EP divisibility) must receive zero routing mass."""
    d, f, B, S, E_real, E_pad = 8, 16, 2, 4, 3, 8
    p = moe_init(jax.random.PRNGKey(1), d, f, E_pad, 0, "silu")
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    y, _ = moe_apply(p, x, top_k=2, n_real=E_real, act="silu", mesh=None,
                     capacity_factor=float(E_pad))
    want = dense_moe_reference(p, x, 2, E_real)
    np.testing.assert_allclose(np.array(y), np.array(want), rtol=2e-3, atol=2e-3)


def test_capacity_drops_reduce_output(rng):
    """With a tiny capacity factor some tokens are dropped (GShard-style);
    the layer must still be finite and differentiable."""
    d, f, B, S, E = 8, 16, 2, 16, 4
    p = moe_init(jax.random.PRNGKey(2), d, f, E, 0, "silu")
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))

    def loss(p):
        y, aux = moe_apply(p, x, top_k=2, n_real=E, act="silu", mesh=None,
                           capacity_factor=0.25)
        return jnp.sum(y * y) + aux

    val, grads = jax.value_and_grad(loss)(p)
    assert bool(jnp.isfinite(val))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


def test_shared_experts_add(rng):
    d, f, B, S, E = 8, 16, 1, 4, 4
    p = moe_init(jax.random.PRNGKey(3), d, f, E, 2, "silu")
    assert "shared" in p and p["shared"]["wi"].shape == (d, 2 * f)
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    y, _ = moe_apply(p, x, top_k=2, n_real=E, act="silu", mesh=None)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
