"""Pallas kernel sweeps: every kernel, shapes x dtypes, vs the ref.py
pure-jnp oracles (which are themselves tested against the paper-equation
oracles).  On CPU the kernels execute in interpret mode — the same kernel
bodies that compile on TPU."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dtw as _dtw_mod  # noqa: F401 (import check)
from repro.kernels import ops, ref

SHAPES_ENV = [(1, 16, 3), (5, 33, 0), (8, 128, 12), (13, 64, 64), (3, 40, 7), (9, 256, 100)]
SHAPES_LB = [
    (3, 5, 32, 6, 4), (9, 130, 64, 20, 2), (1, 1, 16, 16, 8),
    (8, 128, 100, 10, 1), (4, 17, 48, 0, 4), (2, 3, 24, 24, 0),
]
SHAPES_DTW = [(1, 16, 4), (7, 32, 32), (130, 24, 3), (128, 64, None), (5, 48, 0)]


@pytest.mark.parametrize("n,L,w", SHAPES_ENV)
@pytest.mark.parametrize("dtype", [np.float32])
def test_envelope_kernel(rng, n, L, w, dtype):
    b = jnp.array(rng.normal(size=(n, L)).astype(dtype))
    u1, l1 = ops.envelope_op(b, w)
    u2, l2 = ref.envelope_ref(b, w)
    np.testing.assert_allclose(np.array(u1), np.array(u2), rtol=1e-5)
    np.testing.assert_allclose(np.array(l1), np.array(l2), rtol=1e-5)


@pytest.mark.parametrize("Q,C,L,w,v", SHAPES_LB)
def test_lb_keogh_kernel(rng, Q, C, L, w, v):
    q = jnp.array(rng.normal(size=(Q, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(C, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    np.testing.assert_allclose(
        np.array(ops.lb_keogh_op(q, u, lo)),
        np.array(ref.lb_keogh_ref(q, u, lo)),
        rtol=1e-4, atol=1e-5,
    )


@pytest.mark.parametrize("Q,C,L,w,v", SHAPES_LB)
@pytest.mark.parametrize("bands_only", [False, True])
def test_lb_enhanced_kernel(rng, Q, C, L, w, v, bands_only):
    q = jnp.array(rng.normal(size=(Q, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(C, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    got = ops.lb_enhanced_op(q, c, u, lo, w, v, bands_only=bands_only)
    want = ref.lb_enhanced_ref(q, c, u, lo, w, v, bands_only=bands_only)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)


# pairwise survivor shape: odd L, L == 2*nb (empty bridge), tile-padding
# remainders (P=130 spills the 128 tile; P=9 pads to the 8-sublane multiple)
SHAPES_PAIRWISE = [
    (1, 16, 4, 4), (9, 33, 7, 4), (130, 47, 11, 4), (8, 5, 4, 4),
    (12, 21, 21, 8), (5, 64, 0, 4), (16, 128, 12, 0), (7, 4, 4, 4),
]


@pytest.mark.parametrize("P,L,w,v", SHAPES_PAIRWISE)
@pytest.mark.parametrize("bands_only", [False, True])
def test_lb_enhanced_pairwise_kernel(rng, P, L, w, v, bands_only):
    q = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    got = ops.lb_enhanced_pairwise_op(q, c, u, lo, w, v, bands_only=bands_only)
    want = ref.lb_enhanced_pairwise_ref(q, c, u, lo, w, v,
                                        bands_only=bands_only)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)


def test_lb_enhanced_pairwise_matches_cross_block_diagonal(rng):
    """The pairwise kernel is the diagonal of the cross-block kernel."""
    P, L, w, v = 24, 48, 10, 4
    q = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    pair = ops.lb_enhanced_pairwise_op(q, c, u, lo, w, v)
    block = ops.lb_enhanced_op(q, c, u, lo, w, v)
    np.testing.assert_allclose(np.array(pair), np.array(block).diagonal(),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("P,L,w,v", [(9, 33, 7, 4), (130, 47, 11, 4),
                                     (16, 128, 12, 0)])
@pytest.mark.parametrize("bands_only", [False, True])
def test_lb_enhanced_pairwise_live_slots(rng, P, L, w, v, bands_only):
    """Per-slot liveness: dead slots emit -inf (the compaction scatter-max
    identity), live slots are untouched, and an all-dead batch — whole
    skipped tiles — still emits the right shape of -inf."""
    q = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    live = jnp.array(rng.integers(0, 2, size=(P,)).astype(np.int32))
    got = ops.lb_enhanced_pairwise_op(q, c, u, lo, w, v, live=live,
                                      bands_only=bands_only)
    want = ref.lb_enhanced_pairwise_ref(q, c, u, lo, w, v, live=live,
                                        bands_only=bands_only)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)
    full = ops.lb_enhanced_pairwise_op(q, c, u, lo, w, v,
                                       bands_only=bands_only)
    lv = np.array(live).astype(bool)
    np.testing.assert_allclose(np.array(got)[lv], np.array(full)[lv],
                               rtol=1e-6)
    assert np.all(np.array(got)[~lv] == -np.inf)
    dead = ops.lb_enhanced_pairwise_op(q, c, u, lo, w, v,
                                       live=jnp.zeros((P,), jnp.int32),
                                       bands_only=bands_only)
    assert dead.shape == (P,) and np.all(np.array(dead) == -np.inf)


@pytest.mark.parametrize("Q,C,L,w,v", [(9, 130, 64, 20, 2), (3, 5, 32, 6, 4),
                                       (8, 128, 100, 10, 1)])
@pytest.mark.parametrize("bands_only", [False, True])
def test_lb_enhanced_cross_block_live_candidates(rng, Q, C, L, w, v,
                                                 bands_only):
    """Liveness parity for the dense cross-block tier (the pairwise
    kernel's PR 4 contract): dead candidates emit -inf down their whole
    output column (the running-max identity), live columns are bit-equal
    to the unmasked kernel, and an all-dead store — whole skipped
    candidate tiles — still emits the right shape of -inf."""
    q = jnp.array(rng.normal(size=(Q, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(C, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    live = jnp.array(rng.integers(0, 2, size=(C,)).astype(np.int32))
    got = ops.lb_enhanced_op(q, c, u, lo, w, v, live=live,
                             bands_only=bands_only)
    want = ref.lb_enhanced_ref(q, c, u, lo, w, v, live=live,
                               bands_only=bands_only)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)
    full = ops.lb_enhanced_op(q, c, u, lo, w, v, bands_only=bands_only)
    lv = np.array(live).astype(bool)
    np.testing.assert_array_equal(np.array(got)[:, lv],
                                  np.array(full)[:, lv])
    assert np.all(np.array(got)[:, ~lv] == -np.inf)
    dead = ops.lb_enhanced_op(q, c, u, lo, w, v,
                              live=jnp.zeros((C,), jnp.int32),
                              bands_only=bands_only)
    assert dead.shape == (Q, C) and np.all(np.array(dead) == -np.inf)


def test_enhanced_all_pairs_live_mask(rng):
    """The dense tier's bound fn threads the candidate mask through its
    chunked kernel calls (the planner's dense limit-mask lever)."""
    from repro.search import CascadeConfig, build_index, enhanced_all_pairs
    Q, C, L, w = 5, 37, 33, 8
    q = jnp.array(rng.normal(size=(Q, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(C, L)).astype(np.float32))
    idx = build_index(c, w)
    cfg = CascadeConfig(w=w, v=4, candidate_chunk=16)
    live = jnp.array(rng.integers(0, 2, size=(C,)).astype(np.int32))
    got = np.array(enhanced_all_pairs(q, idx, cfg, live=live))
    want = np.array(enhanced_all_pairs(q, idx, cfg))
    lv = np.array(live).astype(bool)
    np.testing.assert_array_equal(got[:, lv], want[:, lv])
    assert np.all(got[:, ~lv] == -np.inf)


def test_lb_enhanced_pairwise_tile_sweep(rng):
    """VMEM tile shrink: any pair-tile size gives identical bounds."""
    from repro.kernels.lb_enhanced_pairwise import lb_enhanced_pairwise_pallas
    P, L, w, v = 60, 40, 9, 4
    q = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    a = lb_enhanced_pairwise_pallas(q, c, u, lo, w, v, tile_p=8,
                                    interpret=True)
    b = lb_enhanced_pairwise_pallas(q, c, u, lo, w, v, tile_p=128,
                                    interpret=True)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-6)
    want = ref.lb_enhanced_pairwise_ref(q, c, u, lo, w, v)
    np.testing.assert_allclose(np.array(b), np.array(want), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("P,L,w", SHAPES_DTW)
def test_dtw_band_kernel(rng, P, L, w):
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    np.testing.assert_allclose(
        np.array(ops.dtw_band_op(a, b, w)),
        np.array(ref.dtw_band_ref(a, b, w)),
        rtol=1e-4, atol=1e-5,
    )


def test_dtw_band_f64_interpret(rng):
    """dtype sweep: interpret mode should honour f64 inputs too."""
    import jax
    a = jnp.array(rng.normal(size=(4, 20)))
    b = jnp.array(rng.normal(size=(4, 20)))
    got = ops.dtw_band_op(a.astype(jnp.float32), b.astype(jnp.float32), 5)
    want = ref.dtw_band_ref(a.astype(jnp.float32), b.astype(jnp.float32), 5)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4)


def test_long_series_fallback(rng):
    """Series beyond the kernel VMEM budget route to the jnp reference."""
    L = 70000   # > envelope kernel budget
    b = jnp.array(rng.normal(size=(1, L)).astype(np.float32))
    u, lo = ops.envelope_op(b, 10)
    assert u.shape == (1, L) and lo.shape == (1, L)


# ---------------------------------------------------------------------------
# fused mamba selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,C,N,tc,ts", [
    (2, 16, 8, 4, 8, 8), (1, 33, 6, 4, 4, 16), (3, 64, 16, 8, 16, 16),
    (2, 8, 12, 4, 12, 8),
])
def test_mamba_scan_kernel(rng, B, S, C, N, tc, ts):
    from repro.kernels.mamba_scan import mamba_scan_pallas
    from repro.models.mamba import _chunked_selective_scan
    delta = jnp.array(np.abs(rng.normal(size=(B, S, C))).astype(np.float32))
    u = jnp.array(rng.normal(size=(B, S, C)).astype(np.float32))
    A = -jnp.array(np.abs(rng.normal(size=(C, N))).astype(np.float32))
    Bm = jnp.array(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.array(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.array(rng.normal(size=(B, C, N)).astype(np.float32))
    y1, h1 = mamba_scan_pallas(delta, u, A, Bm, Cm, h0,
                               tile_c=tc, tile_s=ts, interpret=True)
    y2, h2 = _chunked_selective_scan(delta, u, A, Bm, Cm, h0, chunk=8)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(h1), np.array(h2), rtol=1e-3, atol=1e-4)


def test_mamba_scan_op_gradients(rng):
    """custom_vjp backward (recompute through the chunked scan) must match
    differentiating the chunked scan directly."""
    import jax
    from repro.kernels.ops import mamba_scan_op
    from repro.models.mamba import _chunked_selective_scan
    B, S, C, N = 1, 16, 4, 4
    delta = jnp.array(np.abs(rng.normal(size=(B, S, C))).astype(np.float32))
    u = jnp.array(rng.normal(size=(B, S, C)).astype(np.float32))
    A = -jnp.array(np.abs(rng.normal(size=(C, N))).astype(np.float32))
    Bm = jnp.array(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.array(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.zeros((B, C, N))

    def loss_k(d):
        y, h = mamba_scan_op(d, u, A, Bm, Cm, h0)
        return jnp.sum(y * y) + jnp.sum(h)

    def loss_r(d):
        y, h = _chunked_selective_scan(d, u, A, Bm, Cm, h0, chunk=8)
        return jnp.sum(y * y) + jnp.sum(h)

    g1 = jax.grad(loss_k)(delta)
    g2 = jax.grad(loss_r)(delta)
    np.testing.assert_allclose(np.array(g1), np.array(g2), rtol=1e-3, atol=1e-4)


def test_mamba_pallas_impl_in_model(rng):
    """ssm_impl='pallas' must reproduce the scan path end to end."""
    import jax
    from repro.configs.registry import ARCHS, reduced
    from repro.models.model import LM
    import dataclasses
    r = reduced(ARCHS["falcon-mamba-7b"])
    m1 = LM(cfg=r, mesh=None, remat=False, ssm_impl="scan")
    m2 = LM(cfg=r, mesh=None, remat=False, ssm_impl="pallas")
    params = m1.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.array(rng.integers(0, r.vocab, size=(2, 16)), jnp.int32),
        "labels": jnp.array(rng.integers(0, r.vocab, size=(2, 16)), jnp.int32),
    }
    l1, _ = m1.loss_fn(params, batch)
    l2, _ = m2.loss_fn(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)


def test_lb_enhanced_kernel_large_query_tile(rng):
    """SSPerf hillclimb C: tile_q=64 (one candidate-store pass per 64
    queries) must be bit-identical to the default tiling."""
    from repro.kernels.lb_enhanced import lb_enhanced_pallas
    Q, C, L, w, v = 80, 130, 96, 28, 4
    q = jnp.array(rng.normal(size=(Q, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(C, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    a = lb_enhanced_pallas(q, c, u, lo, w, v, tile_q=8, interpret=True)
    b = lb_enhanced_pallas(q, c, u, lo, w, v, tile_q=64, interpret=True)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-6)
    want = ref.lb_enhanced_ref(q, c, u, lo, w, v)
    np.testing.assert_allclose(np.array(b), np.array(want), rtol=1e-4, atol=1e-5)


def test_lb_keogh_kernel_large_query_tile(rng):
    from repro.kernels.lb_keogh import lb_keogh_pallas
    Q, C, L, w = 70, 100, 64, 12
    q = jnp.array(rng.normal(size=(Q, L)).astype(np.float32))
    c = jnp.array(rng.normal(size=(C, L)).astype(np.float32))
    u, lo = ops.envelope_op(c, w)
    a = lb_keogh_pallas(q, u, lo, tile_q=64, interpret=True)
    want = ref.lb_keogh_ref(q, u, lo)
    np.testing.assert_allclose(np.array(a), np.array(want), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,cap", [
    (2, 32, 4, 2, 8, True, None, None),
    (1, 48, 6, 1, 8, True, None, None),
    (2, 33, 4, 4, 8, False, None, None),
    (1, 64, 2, 2, 8, True, 16, None),
    (1, 32, 2, 2, 8, True, None, 30.0),
])
def test_flash_attention_kernel(rng, B, S, Hq, Hkv, D, causal, window, cap):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import flash_attention
    q = jnp.array(rng.normal(size=(B, S, Hq, D)).astype(np.float32))
    k = jnp.array(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    v = jnp.array(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 score_cap=cap, tile_q=8, tile_k=8,
                                 interpret=True)
    want = flash_attention(q, k, v, pos, pos, causal=causal, window=window,
                           score_cap=cap, kv_chunk=8)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_op_gradients(rng):
    import jax
    from repro.kernels.ops import flash_attention_op
    from repro.models.attention import flash_attention
    B, S, H, D = 1, 16, 2, 8
    q = jnp.array(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.array(rng.normal(size=(B, S, H, D)).astype(np.float32))
    v = jnp.array(rng.normal(size=(B, S, H, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    g1 = jax.grad(lambda qq: jnp.sum(flash_attention_op(qq, k, v) ** 2))(q)
    g2 = jax.grad(lambda qq: jnp.sum(
        flash_attention(qq, k, v, pos, pos, kv_chunk=8) ** 2))(q)
    np.testing.assert_allclose(np.array(g1), np.array(g2), rtol=2e-3, atol=2e-3)


def test_attn_pallas_impl_in_model(rng):
    """attn_impl='pallas' must reproduce the chunked path end to end."""
    import jax
    from repro.configs.registry import ARCHS, reduced
    from repro.models.model import LM
    r = reduced(ARCHS["gemma2-2b"])   # local+global windows + softcap
    m1 = LM(cfg=r, mesh=None, remat=False, attn_impl="chunked")
    m2 = LM(cfg=r, mesh=None, remat=False, attn_impl="pallas")
    params = m1.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.array(rng.integers(0, r.vocab, size=(2, 16)), jnp.int32),
        "labels": jnp.array(rng.integers(0, r.vocab, size=(2, 16)), jnp.int32),
    }
    l1, _ = m1.loss_fn(params, batch)
    l2, _ = m2.loss_fn(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)
