"""Data-substrate tests."""

import numpy as np

from repro.data import make_dataset, random_pairs


def test_dataset_shapes_and_norms():
    ds = make_dataset(n_classes=3, n_train_per_class=5, n_test_per_class=2,
                      length=32, seed=0)
    assert ds.x_train.shape == (15, 32)
    assert ds.x_test.shape == (6, 32)
    assert ds.n_classes == 3
    np.testing.assert_allclose(ds.x_train.mean(axis=1), 0.0, atol=1e-4)
    np.testing.assert_allclose(ds.x_train.std(axis=1), 1.0, atol=1e-2)
    assert set(np.unique(ds.y_train)) == {0, 1, 2}


def test_dataset_deterministic():
    a = make_dataset(seed=4, length=16, n_train_per_class=3, n_test_per_class=1)
    b = make_dataset(seed=4, length=16, n_train_per_class=3, n_test_per_class=1)
    np.testing.assert_array_equal(a.x_train, b.x_train)


def test_dataset_classes_separable():
    """Different class prototypes should make same-class pairs closer on
    average than cross-class pairs (Euclidean proxy)."""
    ds = make_dataset(n_classes=2, n_train_per_class=20, n_test_per_class=1,
                      length=64, warp=0.3, noise=0.1, seed=2)
    x, y = ds.x_train, ds.y_train
    d = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    same = d[y[:, None] == y[None, :]]
    diff = d[y[:, None] != y[None, :]]
    assert same.mean() < diff.mean()


def test_random_pairs():
    a, b = random_pairs(10, 64, seed=1)
    assert a.shape == b.shape == (10, 64)
    np.testing.assert_allclose(a.mean(axis=1), 0.0, atol=1e-4)
