"""Per-architecture smoke tests: reduced configs (same structure, same
divisibility properties), one forward/train step on CPU, output shapes +
no NaNs.  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, shape_applicability
from repro.configs.registry import ARCHS, reduced
from repro.models.model import LM
from repro.train import OptConfig, init_state, make_train_step

B, S = 2, 16


def _batch(r, rng_np, with_labels=True):
    batch = {}
    if r.embed_inputs:
        batch["tokens"] = jnp.array(
            rng_np.integers(0, r.vocab, size=(B, S)), jnp.int32
        )
    else:
        batch["frames"] = jnp.array(
            rng_np.normal(size=(B, S, r.d_model)), jnp.bfloat16
        )
    if with_labels:
        batch["labels"] = jnp.array(
            rng_np.integers(0, r.vocab, size=(B, S)), jnp.int32
        )
    if r.vision_prefix:
        batch["vision_embeds"] = jnp.array(
            rng_np.normal(size=(B, r.vision_prefix, r.d_model)), jnp.bfloat16
        )
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (B, 3, S)
        )
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_forward_and_shapes(name, rng):
    r = reduced(ARCHS[name])
    model = LM(cfg=r, mesh=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    loss, metrics = model.loss_fn(params, _batch(r, rng))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    assert bool(jnp.isfinite(metrics["ce"]))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_train_step(name, rng):
    r = reduced(ARCHS[name])
    model = LM(cfg=r, mesh=None, remat=True)
    opt = OptConfig(lr=1e-3, warmup=1)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(r, rng)
    l0 = None
    for _ in range(3):
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"])), name
        l0 = float(m["loss"]) if l0 is None else l0
    assert float(m["loss"]) <= l0 + 0.5, f"{name} diverged"
    assert int(state.step) == 3


@pytest.mark.parametrize(
    "name", [n for n, c in sorted(ARCHS.items()) if c.causal]
)
def test_arch_prefill_decode(name, rng):
    r = reduced(ARCHS[name])
    model = LM(cfg=r, mesh=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(r, rng, with_labels=False)
    logits, caches, idx = model.prefill(params, batch, max_len=S + 2)
    assert logits.shape == (B, r.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), name
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg, caches = model.decode_step(params, caches, tok, jnp.int32(S))
    assert lg.shape == (B, r.vocab)
    assert bool(jnp.all(jnp.isfinite(lg))), name


def test_encoder_has_no_decode():
    r = reduced(ARCHS["hubert-xlarge"])
    model = LM(cfg=r, mesh=None)
    with pytest.raises(ValueError):
        model.decode_step({}, {}, jnp.zeros((1, 1), jnp.int32), jnp.int32(0))


def test_decode_consistency_with_prefill(rng):
    """Teacher-forced equivalence at the full-model level: the logits for
    position t from (prefill to t-1, decode t) match full prefill."""
    r = reduced(ARCHS["qwen2.5-3b"])
    model = LM(cfg=r, mesh=None, remat=False, cache_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.array(rng.integers(0, r.vocab, size=(B, S)), jnp.int32)
    full_logits, _, _ = model.prefill(params, {"tokens": toks})
    part_logits, caches, _ = model.prefill(
        params, {"tokens": toks[:, : S - 1]}, max_len=S
    )
    step_logits, _ = model.decode_step(
        params, caches, toks[:, S - 1 :], jnp.int32(S - 1)
    )
    np.testing.assert_allclose(
        np.array(full_logits), np.array(step_logits), rtol=3e-2, atol=3e-2
    )


def test_cell_grid_accounting():
    """40 cells total: 32 runnable + 8 documented skips (DESIGN.md SS5)."""
    runnable = skipped = 0
    for cfg in ARCHS.values():
        for s in SHAPES.values():
            if shape_applicability(cfg, s) is None:
                runnable += 1
            else:
                skipped += 1
    assert runnable + skipped == 40
    assert skipped == 8
