"""Streaming DMA-pipeline DTW: parity, geometry budget, tile threading.

Parity bar: the streaming grid runs the same ``band_step`` recurrence on
the same ``row_block_policy`` boundaries as the resident grid — only the
memory movement differs — so streaming and resident kernels must be
**bit-equal in every configuration** (windows, cutoffs, odd lengths, tile
padding, dead tiles).  Against the jnp ``dtw_band_blocked`` reference the
assertion is float-exact up to XLA re-fusion: the shared recurrence can
be contracted differently across compilation contexts (the *resident*
kernel shows the same occasional 1-ulp drift vs the ref), so vs-ref
checks use ``rtol=1e-6`` — far below any semantic difference.

The exhaustive w in {0, 1, L/4, L} x cutoff x odd-length cross product
runs at small L with the streaming path *forced* (the grids are
length-independent, so small-L coverage exercises every code path);
lengths straddling the old 16384 ceiling run the cheap windows only —
w = L/4 at L = 32k is a ~16k-lane band state swept 65k times, beyond
what interpret mode can pay per test.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dtw import dtw_band_blocked, row_block_policy
from repro.kernels import ops, ref
from repro.kernels.dtw_band import _VMEM_BUDGET, dtw_band_pallas
from repro.kernels.tiling import sched_pair_tile, stream_geometry

L_SMALL = 129                       # odd: exercises parity masking


def _pair(rng, P, L):
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    return a, b


# ---------------------------------------------------------------------------
# forced-streaming parity sweep at small L (full cross product)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P,L", [(13, L_SMALL), (8, 96), (1, 40)])
@pytest.mark.parametrize("wsel", ["0", "1", "L/4", "L"])
@pytest.mark.parametrize("with_cutoff", [False, True])
def test_stream_matches_resident_bitwise(rng, P, L, wsel, with_cutoff):
    w = {"0": 0, "1": 1, "L/4": L // 4, "L": L}[wsel]
    a, b = _pair(rng, P, L)
    if with_cutoff:
        plain = np.array(ref.dtw_band_ref(a, b, w))
        # mixed liveness: even lanes abandon, odd lanes finish exactly
        cut = jnp.array(np.where(np.arange(P) % 2 == 0, plain * 0.5,
                                 plain * 2.0 + 1.0).astype(np.float32))
    else:
        cut = None
    st = np.array(dtw_band_pallas(a, b, w, cut, stream=True, tile_p=8,
                                  interpret=True))
    rs = np.array(dtw_band_pallas(a, b, w, cut, tile_p=8, interpret=True))
    np.testing.assert_array_equal(st, rs)
    want = np.array(dtw_band_blocked(a, b, w, cut))
    np.testing.assert_allclose(st, want, rtol=1e-6)


def test_stream_lone_survivor_tile(rng):
    """One live lane pins its tile: every other lane is poisoned, the
    survivor's value is exact — across the streaming DMA pipeline."""
    P, L, w = 16, 64, 8
    a, b = _pair(rng, P, L)
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut_np = (plain * 1e-3).astype(np.float32)
    cut_np[7] = np.inf
    got = np.array(dtw_band_pallas(a, b, w, jnp.array(cut_np), stream=True,
                                   row_block=8, tile_p=8, interpret=True))
    np.testing.assert_allclose(got[7], plain[7], rtol=1e-4, atol=1e-5)
    assert np.all(np.isinf(np.delete(got, 7)))


def test_stream_all_dead_tile(rng):
    """A fully-poisoned tile stops issuing DMAs and still emits +inf for
    every lane (the drained-pipeline output path)."""
    P, L, w = 8, 64, 16
    a, b = _pair(rng, P, L)
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut = jnp.array((plain * 1e-3).astype(np.float32))
    got = np.array(dtw_band_pallas(a, b, w, cut, stream=True, row_block=16,
                                   interpret=True))
    assert np.all(np.isinf(got))
    want = np.array(ref.dtw_band_ref(a, b, w, cut, row_block=16))
    np.testing.assert_allclose(got, want)


def test_stream_row_block_override_is_result_invariant(rng):
    """Abandon decisions move with the block boundary but values do not
    (frontier minima are monotone) — any row_block gives the same output."""
    P, L, w = 9, 80, 12
    a, b = _pair(rng, P, L)
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut = jnp.array((plain * np.linspace(0.3, 3.0, P)).astype(np.float32))
    outs = [
        np.array(dtw_band_pallas(a, b, w, cut, stream=True, row_block=rb,
                                 tile_p=8, interpret=True))
        for rb in (8, 32, None)
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# lengths straddling the old 16384 ceiling (cheap windows only — see header)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L", [16384, 16392, 32768])
def test_stream_across_old_length_ceiling(rng, L):
    P, w = 2, 1
    a, b = _pair(rng, P, L)
    st = np.array(dtw_band_pallas(a, b, w, stream=True, interpret=True))
    want = np.array(dtw_band_blocked(a, b, w))
    np.testing.assert_allclose(st, want, rtol=1e-6)
    # cutoff: lane 0 exact, lane 1 abandons
    cut = jnp.array([want[0] * 2 + 1, want[1] * 0.5], dtype=jnp.float32)
    st_c = np.array(dtw_band_pallas(a, b, w, cut, stream=True,
                                    interpret=True))
    want_c = np.array(dtw_band_blocked(a, b, w, cut))
    np.testing.assert_allclose(st_c, want_c, rtol=1e-6)
    assert np.isinf(st_c[1]) and np.isfinite(st_c[0])


def test_dtw_band_op_accepts_L65536(rng):
    """The acceptance bar: no _DTW_MAX_L — the op streams at L = 65536."""
    P, L, w = 2, 65536, 1
    a, b = _pair(rng, P, L)
    got = np.array(ops.dtw_band_op(a, b, w))
    want = np.array(dtw_band_blocked(a, b, w))
    assert got.shape == (P,) and np.all(np.isfinite(got))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_dtw_band_op_streams_past_residency(rng):
    """Just past the crossover the op routes to the streaming kernel and
    matches the reference (cutoff semantics included)."""
    P, L, w = 3, ops._DTW_RESIDENT_MAX_L + 8, 2
    a, b = _pair(rng, P, L)
    want = np.array(dtw_band_blocked(a, b, w))
    got = np.array(ops.dtw_band_op(a, b, w))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    cut = jnp.array([np.inf, 0.0, np.inf], jnp.float32)
    got_c = np.array(ops.dtw_band_op(a, b, w, cut))
    assert np.isinf(got_c[1]) and np.isfinite(got_c[0])


def test_stream_unfittable_band_falls_back_to_ref(rng, monkeypatch):
    """w so wide the band state alone exceeds VMEM at the sublane floor:
    stream_geometry says None and the op routes to the jnp reference.
    (Executing that shape is O(L^2) work on any path — too costly for a
    test — so the dispatch decision is asserted via a sentinel.)"""
    L = ops._DTW_RESIDENT_MAX_L + 8
    assert stream_geometry(L, L - 1, 128, 2, _VMEM_BUDGET) is None
    P = 2
    a, b = _pair(rng, P, L)
    sentinel = jnp.full((P,), 42.0, jnp.float32)
    monkeypatch.setattr(ops.ref, "dtw_band_ref",
                        lambda *a_, **kw: sentinel)
    out = np.array(ops.dtw_band_op(a, b, None))
    np.testing.assert_array_equal(out, 42.0)


# ---------------------------------------------------------------------------
# streaming geometry budget
# ---------------------------------------------------------------------------

def test_stream_geometry_fits_budget():
    budget = _VMEM_BUDGET
    for L, w in [(2048, 205), (16384, 64), (65536, 655), (65536, 4096)]:
        geom = stream_geometry(L, w, 128, 1024, budget)
        assert geom is not None, (L, w)
        tile, R = geom
        Wb = -(-(2 * w + 1) // 128) * 128
        Wwin = -(-(R + Wb) // 128) * 128
        per_row = (4 * Wwin + 8 * Wb) * 4
        assert tile * per_row <= budget
        assert tile % 8 == 0 and tile >= 8
        assert R >= 1


def test_stream_geometry_prefers_shared_policy():
    """When the policy block fits (and clears the streaming amortisation
    floor), streaming and the jnp reference make abandon decisions on
    identical boundaries; the floor itself is the band-width-aware
    ``stream_pref_block`` policy, not a hard-coded constant."""
    from repro.kernels.tiling import stream_pref_block

    L, w = 8192, 410
    geom = stream_geometry(L, w, 8, 8, _VMEM_BUDGET)
    assert geom is not None and geom[1] == row_block_policy(L)
    # wide band at a short length: the policy floor (320 steps at wb=205)
    # no longer binds — the shared ~8-block policy wins, where the old
    # 1024-step hard floor forced 4 oversized blocks
    geom = stream_geometry(2048, 205, 8, 8, _VMEM_BUDGET)
    assert geom is not None
    assert geom[1] == max(row_block_policy(2048), stream_pref_block(205))
    assert geom[1] < 1024
    # an explicit measured floor overrides the policy
    geom = stream_geometry(2048, 205, 8, 8, _VMEM_BUDGET, pref_block=1024)
    assert geom is not None and geom[1] == 1024


def test_stream_pref_block_policy_bounds():
    from repro.kernels.tiling import stream_pref_block

    # narrow bands (one lane group) keep the old 1024-step floor
    assert stream_pref_block(1) == 1024
    assert stream_pref_block(63) == 1024
    # wider bands amortise DMA issue with smaller blocks, floor 64
    assert stream_pref_block(205) < 1024
    assert all(stream_pref_block(wb) >= 64 for wb in (1, 205, 4096, 10**6))
    assert all(stream_pref_block(wb) % 64 == 0 for wb in (1, 77, 205, 4096))


# ---------------------------------------------------------------------------
# schedule-aware pair-tile sizing (geometry only — results invariant)
# ---------------------------------------------------------------------------

def test_dtw_band_op_tile_p_is_result_invariant(rng):
    P, L, w = 40, 64, 9
    a, b = _pair(rng, P, L)
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut = jnp.array(np.where(np.arange(P) % 3 == 0, plain * 0.5,
                             plain * 2.0).astype(np.float32))
    perm = jnp.array(rng.permutation(P))
    base = np.array(ops.dtw_band_op(a, b, w, cut))
    for tp in (8, 16, 128):
        np.testing.assert_array_equal(
            np.array(ops.dtw_band_op(a, b, w, cut, tile_p=tp)), base)
        np.testing.assert_array_equal(
            np.array(ops.dtw_band_op(a, b, w, cut, tile_p=tp, perm=perm)),
            base)
    # the reference accepts (and ignores) the same hint — one call shape
    np.testing.assert_array_equal(
        np.array(ref.dtw_band_ref(a, b, w, cut, tile_p=8)),
        np.array(ref.dtw_band_ref(a, b, w, cut)))


def test_sched_pair_tile_policy_bounds():
    for P in (8, 64, 512, 4096, 100000):
        t = sched_pair_tile(P)
        assert 8 <= t <= 128 and t % 8 == 0
    assert sched_pair_tile(512) == 32          # typical engine round
    assert sched_pair_tile(100000) == 128      # huge rounds keep full tiles
