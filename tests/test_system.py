"""End-to-end system tests: the paper's pipeline and the LM substrate."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_dataset
from repro.search import CascadeConfig, EngineConfig, build_index, classify


def test_nn_dtw_classification_end_to_end():
    """The paper's headline pipeline: envelopes -> cascade -> verified
    NN-DTW classification, with real pruning and high accuracy."""
    ds = make_dataset(n_classes=4, n_train_per_class=25, n_test_per_class=6,
                      length=96, seed=11)
    w = int(0.1 * ds.length)
    idx = build_index(ds.x_train, w, ds.y_train)
    cfg = EngineConfig(cascade=CascadeConfig(w=w, v=4), verify_chunk=16, k=1)
    pred, res = classify(idx, ds.x_test, cfg)
    acc = float(np.mean(np.array(pred) == ds.y_test))
    prune = float(np.mean(np.array(res.pruning_power())))
    assert acc >= 0.75, f"accuracy {acc}"
    assert prune >= 0.3, f"pruning power {prune}"


def test_lb_enhanced_tightness_dominates_keogh_in_aggregate():
    """Fig. 1 qualitative claim: mean tightness ENHANCED^4 > KEOGH."""
    from repro.core import dtw_pairs, envelope, lb_enhanced_matrix, lb_keogh_matrix
    from repro.data import random_pairs
    a, b = random_pairs(48, 64, seed=3)
    w = int(0.3 * 64)
    u, lo = envelope(jnp.array(b), w)
    keogh = np.diagonal(np.array(lb_keogh_matrix(jnp.array(a), u, lo)))
    enh = np.diagonal(np.array(
        lb_enhanced_matrix(jnp.array(a), jnp.array(b), u, lo, w, 4)
    ))
    d = np.diagonal(np.array(dtw_pairs(jnp.array(a), jnp.array(b), w)))
    t_k = np.mean(keogh / d)
    t_e = np.mean(enh / d)
    assert t_e > t_k
    assert np.all(enh <= d * (1 + 1e-4))


def test_lm_trains_end_to_end(tmp_path):
    """Tiny LM: a few steps of training reduce loss; checkpoint/restore
    resumes identically (fault-tolerance path)."""
    import dataclasses

    from repro.configs.registry import ARCHS, reduced
    from repro.models.model import LM
    from repro.train import (
        OptConfig, init_state, make_train_step, restore_checkpoint,
        save_checkpoint,
    )

    r = reduced(ARCHS["qwen2.5-3b"])
    model = LM(cfg=r, mesh=None)
    opt = OptConfig(lr=3e-3, warmup=2)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, r.vocab, size=(4, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses

    d = str(tmp_path / "ck")
    save_checkpoint(d, int(state.step), state)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, _ = restore_checkpoint(d, like)
    s1, _ = step(state, batch)
    s2, _ = step(restored, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), rtol=1e-5, atol=1e-6)
