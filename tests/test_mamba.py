"""Mamba correctness: chunked scan vs naive recurrence; decode cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba import (
    _chunked_selective_scan,
    init_mamba_cache,
    mamba_apply,
    mamba_init,
)


def naive_recurrence(delta, u, A, Bm, Cm, h0):
    B, S, C = delta.shape
    h = np.array(h0)
    ys = np.zeros((B, S, C), np.float32)
    dl, uu = np.array(delta), np.array(u)
    Bmn, Cmn = np.array(Bm), np.array(Cm)
    An = np.array(A)
    for t in range(S):
        a = np.exp(dl[:, t][..., None] * An)                # (B, C, N)
        b = (dl[:, t] * uu[:, t])[..., None] * Bmn[:, t][:, None, :]
        h = a * h + b
        ys[:, t] = np.einsum("bcn,bn->bc", h, Cmn[:, t])
    return ys, h


@pytest.mark.parametrize("S,chunk", [(8, 3), (16, 16), (17, 4), (32, 8)])
def test_chunked_scan_matches_recurrence(rng, S, chunk):
    B, C, N = 2, 6, 4
    delta = jnp.array(np.abs(rng.normal(size=(B, S, C))).astype(np.float32))
    u = jnp.array(rng.normal(size=(B, S, C)).astype(np.float32))
    A = -jnp.array(np.abs(rng.normal(size=(C, N))).astype(np.float32))
    Bm = jnp.array(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.array(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.zeros((B, C, N))
    y, h = _chunked_selective_scan(delta, u, A, Bm, Cm, h0, chunk)
    yn, hn = naive_recurrence(delta, u, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.array(y), yn, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(h), hn, rtol=1e-3, atol=1e-4)


def test_decode_matches_full_scan(rng):
    """Stepping one token at a time through the cache must equal running
    the full sequence at once."""
    d, din, N, S, B = 8, 16, 4, 10, 2
    p = mamba_init(jax.random.PRNGKey(0), d, din, N, dt_rank=2)
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    full, _ = mamba_apply(p, x, d_state=N, chunk=4)
    cache = init_mamba_cache(B, din, N, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = mamba_apply(
            p, x[:, t : t + 1], d_state=N, chunk=1, cache=cache
        )
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(full), np.array(step), rtol=2e-3, atol=2e-3)


def test_state_carry_across_segments(rng):
    """Prefill a prefix, then continue: equals the one-shot run."""
    d, din, N, S, B = 8, 16, 4, 12, 1
    p = mamba_init(jax.random.PRNGKey(1), d, din, N, dt_rank=2)
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    full, _ = mamba_apply(p, x, d_state=N, chunk=4)
    cache = init_mamba_cache(B, din, N, dtype=jnp.float32)
    o1, cache = mamba_apply(p, x[:, :7], d_state=N, chunk=4, cache=cache)
    o2, _ = mamba_apply(p, x[:, 7:], d_state=N, chunk=4, cache=cache)
    np.testing.assert_allclose(
        np.array(jnp.concatenate([o1, o2], 1)), np.array(full),
        rtol=2e-3, atol=2e-3,
    )
