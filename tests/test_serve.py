"""Serving-path tests: greedy decode equals teacher-forced argmax."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, reduced
from repro.models.model import LM
from repro.serve import DecodeSession, greedy_decode


def test_greedy_decode_shapes(rng):
    r = reduced(ARCHS["qwen2.5-3b"])
    model = LM(cfg=r, mesh=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.array(rng.integers(0, r.vocab, size=(2, 8)), jnp.int32)
    out = greedy_decode(model, params, prompt, 5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < r.vocab)))


def test_decode_session_matches_prefill_logits(rng):
    """First decoded token from the session == argmax of prefill logits of
    the same prompt re-run with the prompt+token (teacher-forced)."""
    r = reduced(ARCHS["granite-8b"])
    model = LM(cfg=r, mesh=None, remat=False, cache_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(1))
    prompt = jnp.array(rng.integers(0, r.vocab, size=(1, 6)), jnp.int32)
    sess = DecodeSession(model, params, max_len=8)
    logits0 = sess.prefill({"tokens": prompt})
    tok = jnp.argmax(logits0, -1)[:, None].astype(jnp.int32)
    logits1 = sess.step(tok)
    # consistency: running prefill over prompt+tok gives the same logits
    full = jnp.concatenate([prompt, tok], axis=1)
    logits_ref, _, _ = model.prefill(params, {"tokens": full})
    np.testing.assert_allclose(
        np.array(logits1), np.array(logits_ref), rtol=3e-2, atol=3e-2
    )


def test_hybrid_decode_session(rng):
    """Jamba-style hybrid (attn+mamba+moe) decodes through the session."""
    r = reduced(ARCHS["jamba-1.5-large-398b"])
    model = LM(cfg=r, mesh=None, remat=False)
    params = model.init(jax.random.PRNGKey(2))
    prompt = jnp.array(rng.integers(0, r.vocab, size=(2, 5)), jnp.int32)
    out = greedy_decode(model, params, prompt, 4)
    assert out.shape == (2, 4)
