"""Self-tuning tier planner invariants (search/planner.py).

The contract under test:
  * **planner exactness** — any planner-emitted plan (tier dropped /
    reordered / budget-shrunk / limit-masked) returns the same neighbours
    as brute force, and with the conservative default profile
    (``drop_mass_frac=0``: only measured-idle tiers are removed) per-query
    ``n_dtw`` never exceeds the default plan's — across w in
    {0, 1, L/4, L}, k, and skewed stores;
  * calibrate-then-commit: one measurement per (store, window, k,
    config); later searches reuse the committed decision, and store-level
    ``build_index(calibrate=...)`` warms serving so the first real batch
    never pays a calibration block;
  * the expected-value profile (``drop_mass_frac > 0``) may trade a
    bounded handful of verifications for a tier's whole cost class, but
    never exactness;
  * the registry bookkeeping pair ``list_tiers``/``unregister_tier`` is
    idempotent, so calibration experiments cannot leak tiers across
    tests.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import make_dataset
from repro.search import (
    BoundTier,
    CascadeConfig,
    EngineConfig,
    PlannerConfig,
    brute_force,
    build_index,
    calibrate_plan,
    default_plan,
    list_tiers,
    nn_search,
    optimise_plan,
    register_tier,
    run_plan,
    unregister_tier,
)
from repro.search import planner as plr
from repro.search import pipeline as pl

# derandomized: the n_dtw <= property is a statement about the planner's
# decisions on concrete workloads — fixed examples make a pass here a
# pass in CI, not a seed lottery
settings.register_profile("planner-ci", max_examples=10, deadline=None,
                          derandomize=True)
settings.load_profile("planner-ci")

L_TEST = 48


def _setup(w=8, n_per=12, L=L_TEST, seed=0, k=1, verify=4, auto=True, **ckw):
    ds = make_dataset(n_classes=3, n_train_per_class=n_per,
                      n_test_per_class=4, length=L, seed=seed)
    idx = build_index(ds.x_train, w, ds.y_train)
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, candidate_chunk=16,
                              use_pallas=False, **ckw),
        verify_chunk=verify, k=k, auto_plan=auto,
    )
    return ds, idx, cfg


def _committed_decision():
    assert plr.plan_cache_len() >= 1
    return next(iter(plr._PLAN_CACHE.values()))[1]


# ---------------------------------------------------------------------------
# planner exactness: neighbours equal brute force, n_dtw never worse
# ---------------------------------------------------------------------------

@given(
    w=st.sampled_from([0, 1, L_TEST // 4, L_TEST]),
    k=st.integers(1, 3),
    verify=st.integers(1, 9),
    seed=st.integers(0, 1000),
)
def test_auto_plan_exact_and_no_more_dtw(w, k, verify, seed):
    """For every (window, k, chunking, data): the calibrate-then-commit
    search returns brute-force neighbours and per-query n_dtw never
    exceeds the default plan's (conservative profile)."""
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=w, seed=seed, k=k, verify=verify)
    cfg0 = dataclasses.replace(cfg, auto_plan=False)
    res_a = nn_search(idx, ds.x_test, cfg)
    res_0 = nn_search(idx, ds.x_test, cfg0)
    bd, _ = brute_force(idx, ds.x_test, w, k=k, use_pallas=False)
    # exact distances; different plans can re-fuse the same DTW batch, so
    # the comparison is the same allclose the distributed tests use
    np.testing.assert_allclose(np.array(res_a.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.array(res_a.dists),
                               np.array(res_0.dists), rtol=1e-5, atol=1e-6)
    assert np.all(np.array(res_a.n_dtw) <= np.array(res_0.n_dtw))
    # the committed decision replays identically on a warm search
    res_c = nn_search(idx, ds.x_test, cfg)
    np.testing.assert_array_equal(np.array(res_c.idx), np.array(res_a.idx))


def test_auto_plan_exact_with_exclude():
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(k=2)
    q = ds.x_train[:6]
    ex = jnp.arange(6)
    res_a = nn_search(idx, q, cfg, exclude=ex)
    bd, _ = brute_force(idx, q, 8, k=2, exclude=ex)
    np.testing.assert_allclose(np.array(res_a.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.array(res_a.idx[:, 0]) != np.arange(6))


def test_planner_exact_on_skewed_store():
    """Skewed store (all the near-neighbour mass in the first rows): the
    committed plan stays exact and never verifies more."""
    plr.plan_cache_clear()
    rng = np.random.default_rng(7)
    Q, L, N, w, k = 8, 48, 96, 8, 2
    queries = rng.normal(size=(Q, L)).astype(np.float32)
    near = np.repeat(queries, 4, axis=0) \
        + 0.05 * rng.normal(size=(Q * 4, L)).astype(np.float32)
    far = 5.0 + rng.normal(size=(N - Q * 4, L)).astype(np.float32)
    series = np.concatenate([near, far], axis=0).astype(np.float32)
    idx = build_index(series, w)
    casc = CascadeConfig(w=w, v=4, candidate_chunk=32, use_pallas=False)
    cfg = EngineConfig(cascade=casc, verify_chunk=8, k=k, auto_plan=True)
    cfg0 = dataclasses.replace(cfg, auto_plan=False)
    res_a = nn_search(idx, jnp.asarray(queries), cfg)
    res_0 = nn_search(idx, jnp.asarray(queries), cfg0)
    bd, _ = brute_force(idx, queries, w, k=k, use_pallas=False)
    np.testing.assert_allclose(np.array(res_a.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.array(res_a.n_dtw) <= np.array(res_0.n_dtw))
    # the near mass is tiny: the planner either right-sized the packed
    # width or found whole tiers measured-idle and dropped them
    dec = _committed_decision()
    assert dec.dropped or (dec.budget is not None and dec.budget < idx.n)


# ---------------------------------------------------------------------------
# the decisions themselves: drops, limit-masks, the w = L collapse
# ---------------------------------------------------------------------------

def test_planner_drops_idle_bands_tier_at_w0():
    """At w = 0 the bands tier is identically zero (nb = 0): measured
    mass 0, dropped, and n_dtw is bit-equal — removing an idle tier
    leaves no hole."""
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=0)
    res_a = nn_search(idx, ds.x_test, cfg)
    res_0 = nn_search(idx, ds.x_test, dataclasses.replace(cfg,
                                                          auto_plan=False))
    dec = _committed_decision()
    assert "bands" in dec.dropped
    assert "bands" not in dec.order
    np.testing.assert_array_equal(np.array(res_a.n_dtw),
                                  np.array(res_0.n_dtw))


def test_planner_drop_or_mask_at_full_window_L256():
    """The acceptance scenario: on the bench's L=256 workload at w = L
    the bands-tier refinement mass collapses (the O(L) pairwise tier
    crosses nothing the cheap tiers did not already prune at the static
    budget) — the planner drops or limit-masks at least one tier and
    neighbours stay equal to brute force."""
    plr.plan_cache_clear()
    L, Q, w = 256, 4, 256
    ds = make_dataset(n_classes=4, n_train_per_class=48,
                      n_test_per_class=1, length=L, seed=11)
    idx = build_index(ds.x_train, w, ds.y_train)
    casc = CascadeConfig(w=w, use_pallas=False, survivor_budget=64)
    dec = calibrate_plan(jnp.asarray(ds.x_test[:Q]), idx, casc, k=1)
    assert dec.dropped or dec.limit is not None, (
        "planner neither dropped nor limit-masked a tier at w=L"
    )
    assert "enhanced_pairwise" in dec.dropped
    cfg = EngineConfig(cascade=casc, verify_chunk=32, k=1, auto_plan=True)
    res = nn_search(idx, ds.x_test[:Q], cfg)       # commits from the cache
    bd, bi = brute_force(idx, ds.x_test[:Q], w, k=1, use_pallas=False)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.array(res.idx), np.array(bi))


def test_planner_limit_mask_is_ndtw_neutral():
    """A committed refine limit covers the measured survivor mass with
    headroom, so masked slots are exactly the pairs the engine could
    never verify: results and per-query n_dtw match the default plan."""
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=12, seed=7, k=2)
    res_a = nn_search(idx, ds.x_test, cfg)
    res_0 = nn_search(idx, ds.x_test, dataclasses.replace(cfg,
                                                          auto_plan=False))
    dec = _committed_decision()
    assert dec.limit is not None, "expected a committed refine limit"
    assert dec.budget is not None and dec.limit <= dec.budget
    np.testing.assert_allclose(np.array(res_a.dists),
                               np.array(res_0.dists), rtol=1e-5, atol=1e-6)
    assert np.all(np.array(res_a.n_dtw) <= np.array(res_0.n_dtw))


def test_economic_profile_drops_low_mass_tier_exactly():
    """drop_mass_frac > 0 (the expected-value profile) removes a tier
    whose measured mass is positive but negligible; exactness holds (a
    bounded n_dtw trade is the documented price)."""
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=12, seed=0, k=1)
    pcfg = PlannerConfig(drop_mass_frac=0.02)
    casc = cfg.cascade
    dec = calibrate_plan(jnp.asarray(ds.x_test), idx, casc, k=1, pcfg=pcfg)
    base = calibrate_plan(jnp.asarray(ds.x_test), idx, casc, k=1)
    assert len(dec.order) <= len(base.order)
    cfg_e = dataclasses.replace(cfg, planner=pcfg)
    res = nn_search(idx, ds.x_test, cfg_e)
    bd, _ = brute_force(idx, ds.x_test, 12, k=1, use_pallas=False)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)


def test_reorder_puts_best_mass_per_work_first():
    """Surviving all-pairs tiers commit in measured mass/work order (the
    O(1) Kim tier has first-crack attribution when it pays)."""
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=8)
    dec = calibrate_plan(jnp.asarray(ds.x_test), idx, cfg.cascade, k=1)
    st_ = dec.stats
    ap = [n for n, s in zip(st_.names, st_.scopes) if s == "all_pairs"
          and n in dec.order]
    ratios = {n: r for n, r in zip(st_.names, st_.mass_per_work())}
    committed_ap = [n for n in dec.order if n in ap]
    assert committed_ap == sorted(ap, key=lambda n: -ratios[n])


# ---------------------------------------------------------------------------
# calibrate-then-commit bookkeeping
# ---------------------------------------------------------------------------

def test_plan_cache_keys_on_planner_config():
    """Different planner thresholds are different decisions: a search
    with an expected-value profile must not silently reuse the
    conservative profile's committed plan (or vice versa)."""
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=12, seed=0)
    nn_search(idx, ds.x_test, cfg)                       # default profile
    assert plr.plan_cache_len() == 1
    aggressive = dataclasses.replace(
        cfg, planner=PlannerConfig(drop_mass_frac=0.05))
    nn_search(idx, ds.x_test, aggressive)                # re-measures
    assert plr.plan_cache_len() == 2
    plr.plan_cache_clear()


def test_commit_cache_keys_on_store_w_k(monkeypatch):
    """One measurement per (store, window, k, config): repeat searches
    reuse the committed decision; a different window or k re-measures."""
    calls = []
    orig = plr.optimise_plan

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    from repro.search import engine as eng
    monkeypatch.setattr(eng._planner, "optimise_plan", counting)
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=8)
    nn_search(idx, ds.x_test, cfg)
    nn_search(idx, ds.x_test, cfg)                 # committed: no re-measure
    assert len(calls) == 1
    nn_search(idx, ds.x_test, dataclasses.replace(cfg, k=2))
    assert len(calls) == 2
    idx12 = build_index(ds.x_train, 12, ds.y_train)
    cfg12 = dataclasses.replace(
        cfg, cascade=dataclasses.replace(cfg.cascade, w=12))
    nn_search(idx12, ds.x_test, cfg12)
    assert len(calls) == 3
    assert plr.plan_cache_len() == 3
    plr.plan_cache_clear()


def test_build_index_calibration_warms_serving(monkeypatch):
    """Store-level calibration at build time: the first real query batch
    finds a committed plan (no calibration block, no re-measure) and is
    exact."""
    plr.plan_cache_clear()
    ds = make_dataset(n_classes=3, n_train_per_class=12,
                      n_test_per_class=4, length=L_TEST, seed=0)
    casc = CascadeConfig(w=8, v=4, candidate_chunk=16, use_pallas=False)
    cfg = EngineConfig(cascade=casc, verify_chunk=4, k=1, auto_plan=True)
    idx = build_index(ds.x_train, 8, ds.y_train, calibrate=cfg)
    assert plr.plan_cache_len() == 1

    calls = []
    orig = plr.optimise_plan

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    from repro.search import engine as eng
    monkeypatch.setattr(eng._planner, "optimise_plan", counting)
    res = nn_search(idx, ds.x_test, cfg)
    assert not calls, "warm store still paid a calibration block"
    bd, _ = brute_force(idx, ds.x_test, 8, k=1, use_pallas=False)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    plr.plan_cache_clear()


def test_with_stats_reports_measurement_and_decision():
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=8, k=2)
    res, stats = nn_search(idx, ds.x_test, cfg, with_stats=True)
    assert stats.calibrated
    assert stats.plan_tiers == _committed_decision().order
    assert tuple(stats.tiers.names) == ("kim", "bands", "enhanced_pairwise")
    np.testing.assert_array_equal(np.asarray(stats.n_dtw),
                                  np.asarray(res.n_dtw))
    text = stats.table()
    assert "mass/work" in text and "kim" in text and "n_dtw" in text
    # dense cascades have no tier pipeline to measure
    dense = dataclasses.replace(
        cfg, auto_plan=False,
        cascade=dataclasses.replace(cfg.cascade, staged=False))
    with pytest.raises(ValueError, match="staged"):
        nn_search(idx, ds.x_test, dense, with_stats=True)


def test_degenerate_calibration_commits_base_plan_unchanged():
    """A store with duplicate series under LOO calibration measures
    tau = 0 for every sampled query, so no tier ever crosses and the
    measurement is all-zero mass.  The planner must treat that as
    uninformative — commit the base plan unchanged — not drop every tier
    and destroy pruning for the whole store."""
    plr.plan_cache_clear()
    ds = make_dataset(n_classes=3, n_train_per_class=12,
                      n_test_per_class=4, length=L_TEST, seed=0)
    twins = np.concatenate([ds.x_train, ds.x_train], axis=0)
    casc = CascadeConfig(w=8, v=4, candidate_chunk=16, use_pallas=False)
    cfg = EngineConfig(cascade=casc, verify_chunk=4, k=1, auto_plan=True)
    idx = build_index(twins, 8, calibrate=cfg)
    dec = _committed_decision()
    assert dec.dropped == ()
    assert dec.plan is dec.base
    assert dec.budget is None and dec.limit is None
    # pruning still works on real queries against the twinned store
    res = nn_search(idx, ds.x_test, cfg)
    res0 = nn_search(idx, ds.x_test, dataclasses.replace(cfg,
                                                         auto_plan=False))
    assert np.all(np.array(res.n_dtw) <= np.array(res0.n_dtw))
    bd, _ = brute_force(idx, ds.x_test, 8, k=1, use_pallas=False)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    plr.plan_cache_clear()


def test_verify_tile_p_skips_old_contract_dtw_fn():
    """A custom dtw_fn on the pre-tile contract (a, b, w, cutoff) still
    works under a plan that pins verify_tile_p: the executor probes the
    signature and gives it the plain call (tile size is geometry only)."""
    from repro.kernels.ref import dtw_band_ref

    ds, idx, cfg = _setup(w=8)

    def old_dtw(a, b, w, cutoff=None):          # no tile_p kwarg
        return dtw_band_ref(a, b, w, cutoff)

    plan = dataclasses.replace(default_plan(cfg.cascade), verify_tile_p=8)
    res = run_plan(jnp.asarray(ds.x_test), idx, cfg.cascade, plan, k=1,
                   dtw_fn=old_dtw)
    ref = run_plan(jnp.asarray(ds.x_test), idx, cfg.cascade, plan, k=1)
    np.testing.assert_allclose(np.array(res.seed_d), np.array(ref.seed_d),
                               rtol=1e-5, atol=1e-6)


def test_pairwise_survivor_keeps_a_selection_tier():
    """If only a pairwise tier measures mass, the planner must still keep
    one all-pairs tier: the compaction selects survivors by the all-pairs
    running max, and an all-zero selection key would pack arbitrary
    candidates."""
    from repro.search import TierStats

    plan = default_plan(CascadeConfig(w=8, use_pallas=False))
    stats = TierStats(
        names=tuple(t.name for t in plan.tiers),
        costs=tuple(t.cost for t in plan.tiers),
        scopes=tuple(t.scope for t in plan.tiers),
        mass=jnp.asarray([0.0, 0.0, 5.0]),
        scored=jnp.asarray([100.0, 100.0, 40.0]),
        work=jnp.asarray([100.0, 1600.0, 1920.0]),
        pairs=jnp.asarray(100.0),
        queries=jnp.asarray(4.0),
        survivors=jnp.asarray([10.0, 10.0, 10.0, 10.0]),
    )
    dec = optimise_plan(plan, stats, n=100, k=1, base_budget=64)
    kept_scopes = [t.scope for t in dec.plan.tiers]
    assert "pairwise" in kept_scopes and "all_pairs" in kept_scopes
    # the plan is valid (all_pairs ahead of the compaction point) and the
    # resurrected selection tier is not reported dropped
    assert set(dec.dropped) <= {"kim", "bands"} and len(dec.dropped) == 1


def test_plan_cache_keys_on_limit_policy():
    """Two base plans differing only in their compaction limit policy are
    different decisions — no silent cache collision."""
    from repro.search import Compaction

    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=8)
    casc = cfg.cascade
    base = default_plan(casc)

    def policy_a(lb01, B, k):
        return jnp.full((lb01.shape[0],), 4, jnp.int32)

    def policy_b(lb01, B, k):
        return jnp.full((lb01.shape[0],), 6, jnp.int32)

    plan_a = dataclasses.replace(base, compaction=Compaction(budget=8,
                                                            limit_fn=policy_a))
    plan_b = dataclasses.replace(base, compaction=Compaction(budget=8,
                                                            limit_fn=policy_b))
    q = jnp.asarray(ds.x_test)
    dec_a = calibrate_plan(q, idx, casc, 1, plan=plan_a)
    assert plr.lookup_plan(idx, casc, 1, plan_b) is None
    dec_b = calibrate_plan(q, idx, casc, 1, plan=plan_b)
    assert plr.plan_cache_len() == 2
    assert plr.lookup_plan(idx, casc, 1, plan_a) is dec_a
    assert plr.lookup_plan(idx, casc, 1, plan_b) is dec_b
    plr.plan_cache_clear()


def test_optimise_plan_rejects_mismatched_stats():
    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=8)
    casc = cfg.cascade
    plan = default_plan(casc)
    cres = run_plan(jnp.asarray(ds.x_test), idx, casc, plan, k=1,
                    collect_stats=True)
    other = dataclasses.replace(plan, tiers=plan.tiers[1:])
    with pytest.raises(ValueError, match="do not match"):
        optimise_plan(other, cres.stats, n=idx.n, k=1, base_budget=64)


def test_auto_plan_inert_under_tracing():
    """Like the adaptive budget: under jit the base plan runs unchanged
    (no host-side calibration inside a trace) and results stay exact."""
    import jax

    plr.plan_cache_clear()
    ds, idx, cfg = _setup(w=8, k=2)
    fn = jax.jit(lambda q: nn_search(idx, q, cfg).dists)
    d = fn(jnp.asarray(ds.x_test))
    bd, _ = brute_force(idx, ds.x_test, 8, k=2, use_pallas=False)
    np.testing.assert_allclose(np.array(d), np.array(bd),
                               rtol=1e-5, atol=1e-6)
    assert plr.plan_cache_len() == 0
    plr.plan_cache_clear()


# ---------------------------------------------------------------------------
# registry bookkeeping (the calibration-experiment hygiene fix)
# ---------------------------------------------------------------------------

def test_list_and_unregister_tiers_idempotent():
    before = list_tiers()
    assert set(("kim", "bands", "enhanced_pairwise",
                "enhanced_dense")) <= set(before)

    @register_tier("throwaway_probe_tier")
    def throwaway() -> BoundTier:
        return BoundTier("throwaway_probe_tier", cost="O(1)",
                         scope="all_pairs", fn=lambda q, i, c: None)

    assert "throwaway_probe_tier" in list_tiers()
    assert list_tiers() == pl.registered_tiers()
    assert unregister_tier("throwaway_probe_tier") is True
    assert "throwaway_probe_tier" not in list_tiers()
    # idempotent: a second unregister (or a never-registered name) is a
    # calm no-op, so test teardown cannot race
    assert unregister_tier("throwaway_probe_tier") is False
    assert unregister_tier("never_registered") is False
    assert list_tiers() == before
