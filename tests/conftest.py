"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py forces
512 host devices (and tests that need a mesh spawn subprocesses).

Hypothesis guard: four test modules use property tests.  Where the real
``hypothesis`` package is installed (the ``dev`` extra in pyproject.toml)
they run under it unchanged.  Where it is absent, a minimal deterministic
shim is installed into ``sys.modules`` *before collection* (conftest runs
first), so the suite degrades gracefully instead of dying at import: each
``@given`` test runs ``max_examples`` fixed-seed samples drawn from the
declared strategies.  Only the API surface the tests actually use is
shimmed (``given``, ``settings`` profiles, ``strategies.integers``).
"""

import sys
import types
import zlib

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _PROFILES = {"default": {"max_examples": 10}}
    _ACTIVE = ["default"]

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    class _Settings:
        def __init__(self, **kw):
            self._kw = kw

        def __call__(self, fn):                     # @settings(...) decorator
            return fn

        @staticmethod
        def register_profile(name, **kw):
            _PROFILES[name] = kw

        @staticmethod
        def load_profile(name):
            _ACTIVE[0] = name

    def _given(**strategies):
        def deco(fn):
            import functools
            import inspect

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = _PROFILES.get(_ACTIVE[0], {}).get("max_examples") or 10
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode())
                )
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            runner.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies
            ])
            return runner

        return deco

    _mod = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _mod.given = _given
    _mod.settings = _Settings
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
