"""Attention correctness: flash vs naive, masks, GQA, caches, RoPE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    attn_apply,
    attn_init,
    flash_attention,
    init_cache,
)
from repro.models.layers import apply_mrope, apply_rope


def naive_attention(q, k, v, q_pos, kv_pos, causal=True, window=None,
                    score_cap=None, kv_valid=None):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * D**-0.5
    if score_cap is not None:
        s = score_cap * jnp.tanh(s / score_cap)
    ok = jnp.ones((B, 1, 1, Sq, Skv), bool)
    dp = q_pos[:, None, None, :, None] - kv_pos[:, None, None, None, :]
    if causal:
        ok = ok & (dp >= 0)
    if window is not None:
        ok = ok & (dp < window)
    if kv_valid is not None:
        ok = ok & kv_valid[:, None, None, None, :]
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 5, None), (False, None, None), (True, None, 30.0),
])
def test_flash_matches_naive(rng, Hq, Hkv, causal, window, cap):
    B, S, D = 2, 17, 8
    q = jnp.array(rng.normal(size=(B, S, Hq, D)).astype(np.float32))
    k = jnp.array(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    v = jnp.array(rng.normal(size=(B, S, Hkv, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = flash_attention(q, k, v, pos, pos, causal=causal, window=window,
                          score_cap=cap, kv_chunk=5)
    want = naive_attention(q, k, v, pos, pos, causal=causal, window=window,
                           score_cap=cap)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill(rng):
    """Teacher-forcing consistency: attending step-by-step through a cache
    must equal full self-attention."""
    B, S, H, Hkv, D, d = 2, 10, 4, 2, 8, 32
    p = attn_init(jax.random.PRNGKey(0), d, H, Hkv, D)
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = attn_apply(p, x, pos, n_heads=H, n_kv_heads=Hkv, d_head=D,
                         kv_chunk=4)
    cache = init_cache(B, S, Hkv, D, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = attn_apply(
            p, x[:, t : t + 1], pos[:, t : t + 1],
            n_heads=H, n_kv_heads=Hkv, d_head=D,
            cache=cache, cache_index=jnp.int32(t), kv_chunk=4,
        )
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(full), np.array(step), rtol=2e-3, atol=2e-3)


def test_rope_relative_shift_invariance(rng):
    """RoPE dot products depend only on relative positions."""
    B, S, H, D = 1, 6, 2, 8
    q = jnp.array(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.array(rng.normal(size=(B, S, H, D)).astype(np.float32))
    p0 = jnp.arange(S)[None, :]
    p7 = p0 + 7
    s0 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, p0), apply_rope(k, p0))
    s7 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, p7), apply_rope(k, p7))
    np.testing.assert_allclose(np.array(s0), np.array(s7), rtol=1e-3, atol=1e-4)


def test_mrope_equals_rope_for_text(rng):
    """When t == h == w (text tokens), M-RoPE must reduce to plain RoPE."""
    B, S, H, D = 2, 5, 2, 16
    x = jnp.array(rng.normal(size=(B, S, H, D)).astype(np.float32))
    pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos3 = jnp.broadcast_to(jnp.arange(S)[None, None], (B, 3, S))
    a = apply_rope(x, pos1)
    b = apply_mrope(x, pos3, (2, 3, 3))
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4, atol=1e-5)


def test_sliding_window_blocks_far_tokens(rng):
    """A key outside the window must not influence the query."""
    B, S, H, D, d = 1, 12, 2, 8, 16
    p = attn_init(jax.random.PRNGKey(1), d, H, H, D)
    x = jnp.array(rng.normal(size=(B, S, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    base, _ = attn_apply(p, x, pos, n_heads=H, n_kv_heads=H, d_head=D,
                         window=3, kv_chunk=4)
    x2 = x.at[:, 0].add(100.0)   # outside window of the last query
    pert, _ = attn_apply(p, x2, pos, n_heads=H, n_kv_heads=H, d_head=D,
                         window=3, kv_chunk=4)
    np.testing.assert_allclose(np.array(base[:, -1]), np.array(pert[:, -1]),
                               rtol=1e-3, atol=1e-3)
