"""Sharding-rule invariants: every param/cache spec divides its dim for
every arch on the production mesh shape (checked structurally against a
mesh stub — no devices needed)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.distributed.sharding import AxisRules, param_spec
from repro.models.model import LM


@dataclasses.dataclass(frozen=True)
class MeshStub:
    axis_names: tuple
    _shape: dict

    @property
    def shape(self):
        return self._shape


SINGLE = MeshStub(("data", "model"), {"data": 16, "model": 16})
MULTI = MeshStub(("pod", "data", "model"), {"pod": 2, "data": 16, "model": 16})


def _axis_product(mesh, entry):
    if entry is None:
        return 1
    total = 1
    for a in entry if isinstance(entry, tuple) else (entry,):
        total *= mesh.shape[a]
    return total


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_divide(name, mesh):
    cfg = ARCHS[name]
    rules = AxisRules.for_mesh(mesh) if hasattr(AxisRules, "for_mesh") else AxisRules()
    rules = AxisRules(dp=("pod", "data")) if "pod" in mesh.axis_names else AxisRules()
    model = LM(cfg=cfg, mesh=None)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        spec = param_spec(cfg, mesh, rules, path, leaf)
        assert len(spec) == leaf.ndim
        for dim, entry in zip(leaf.shape, spec):
            size = _axis_product(mesh, entry)
            assert dim % size == 0, (name, path, leaf.shape, spec)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_no_param_axis_double_booked(name):
    """A mesh axis may appear at most once in any leaf's PartitionSpec."""
    cfg = ARCHS[name]
    rules = AxisRules()
    model = LM(cfg=cfg, mesh=None)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        spec = param_spec(cfg, SINGLE, rules, path, leaf)
        seen = []
        for entry in spec:
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if a is not None:
                    assert a not in seen, (name, path, spec)
                    seen.append(a)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_big_leaves_are_sharded(name):
    """Every leaf >= 8 MB must shard on at least one axis (memory hygiene:
    nothing big may silently replicate 256 ways)."""
    cfg = ARCHS[name]
    rules = AxisRules()
    model = LM(cfg=cfg, mesh=None)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        nbytes = leaf.size * 4
        if nbytes < 8 * 2**20:
            continue
        spec = param_spec(cfg, SINGLE, rules, path, leaf)
        total = 1
        for entry in spec:
            total *= _axis_product(SINGLE, entry)
        assert total > 1, (name, jax.tree_util.keystr(path), leaf.shape)
