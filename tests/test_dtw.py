"""DTW correctness against the loop-based paper-equation oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_matrix, dtw, dtw_batch, dtw_pairs, oracle

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _series(rng, L):
    return rng.normal(size=L).astype(np.float32)


@pytest.mark.parametrize("L,w", [(8, 2), (16, 0), (16, 16), (33, 7), (64, 20)])
def test_dtw_matches_oracle(rng, L, w):
    a, b = _series(rng, L), _series(rng, L)
    assert np.allclose(float(dtw(jnp.array(a), jnp.array(b), w)),
                       oracle.dtw(a, b, w), rtol=1e-4)


def test_dtw_w0_is_squared_euclidean(rng):
    a, b = _series(rng, 32), _series(rng, 32)
    assert np.allclose(float(dtw(jnp.array(a), jnp.array(b), 0)),
                       float(np.sum((a - b) ** 2)), rtol=1e-4)


def test_dtw_identity_is_zero(rng):
    a = _series(rng, 40)
    assert float(dtw(jnp.array(a), jnp.array(a), 5)) == pytest.approx(0.0, abs=1e-5)


def test_cost_matrix_corner_equals_dtw(rng):
    a, b = _series(rng, 24), _series(rng, 24)
    cm = cost_matrix(jnp.array(a), jnp.array(b), 6)
    assert np.allclose(float(cm[-1, -1]), oracle.dtw(a, b, 6), rtol=1e-4)


@given(
    L=st.integers(4, 24),
    w1=st.integers(0, 24),
    w2=st.integers(0, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_dtw_monotone_in_window(L, w1, w2, seed):
    """Widening the window can only lower (or keep) the DTW value."""
    rng = np.random.default_rng(seed)
    a, b = _series(rng, L), _series(rng, L)
    lo, hi = min(w1, w2), max(w1, w2)
    d_lo = float(dtw(jnp.array(a), jnp.array(b), lo))
    d_hi = float(dtw(jnp.array(a), jnp.array(b), hi))
    assert d_hi <= d_lo * (1 + 1e-5) + 1e-6


def test_batch_and_pairs_consistent(rng):
    a = rng.normal(size=(3, 20)).astype(np.float32)
    b = rng.normal(size=(5, 20)).astype(np.float32)
    m = np.array(dtw_pairs(jnp.array(a), jnp.array(b), 4))
    for i in range(3):
        for j in range(5):
            assert np.allclose(m[i, j], oracle.dtw(a[i], b[j], 4), rtol=1e-4)
    d = np.array(dtw_batch(jnp.array(a), jnp.array(a), 4))
    assert np.allclose(d, 0.0, atol=1e-5)
