"""Tier-(-1) quantised sketch: admissibility, parity, masking, exactness.

The sketch store (search/index.py) buys its 32 bytes/candidate with one
invariant — outward quantisation means the dequantised segment envelope
always *contains* the true one, so

    LB_sketch <= LB_Keogh <= DTW_w

holds for every (query, candidate) pair at any window.  Everything here
pins that chain and what is built on it: kernel/reference parity, the
store-level candidate mask's exactness (bit-equal neighbours, and on the
calibration distribution never more DTW than the sketchless default
plan), and the degenerate shapes (w = 0, w = L, odd lengths, ragged
segments, zero-variance series) where rounding bugs hide.
"""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lower_bounds import lb_keogh_env
from repro.kernels import ref
from repro.kernels.ops import sketch_bound_op
from repro.kernels.sketch import sketch_bound_pallas
from repro.search.cascade import CascadeConfig, run_plan
from repro.search.engine import EngineConfig, brute_force, nn_search
from repro.search.index import (
    build_index,
    sketch_features,
    sketch_query_means,
    sketch_segment_sizes,
    sketch_segments,
)
from repro.search.pipeline import default_plan, get_tier
from repro.search.planner import calibration_sample, plan_cache_clear


def _walks(rng, n, L):
    return np.cumsum(
        rng.normal(size=(n, L)), axis=1
    ).astype(np.float32)


def _sketch_bound(index, q):
    s = index.sk_lo.shape[1]
    qbar = sketch_query_means(jnp.asarray(q, jnp.float32), s)
    seg = sketch_segment_sizes(index.length, s)
    return ref.sketch_bound_ref(qbar, index.sk_lo, index.sk_hi,
                                index.sk_scale, seg)


# ---------------------------------------------------------------------------
# admissibility: LB_sketch <= LB_Keogh <= DTW_w, every window, every shape
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L", [64, 37, 8])          # even, odd/ragged, S > L
@pytest.mark.parametrize("wsel", ["0", "1", "L/4", "L"])
def test_sketch_admissible_under_keogh_and_dtw(rng, L, wsel):
    w = {"0": 0, "1": 1, "L/4": L // 4, "L": L}[wsel]
    store = _walks(rng, 24, L)
    qs = _walks(rng, 5, L)
    index = build_index(store, w)
    sb = np.array(_sketch_bound(index, qs))
    keogh = np.array(
        jnp.stack([
            jnp.stack([
                lb_keogh_env(jnp.asarray(q), index.upper[n], index.lower[n])
                for n in range(index.n)
            ])
            for q in jnp.asarray(qs)
        ])
    )
    assert np.all(sb <= keogh * (1 + 1e-5) + 1e-5), (
        f"sketch exceeds LB_Keogh at w={w}, L={L}"
    )
    d = np.array(ref.dtw_band_ref(
        jnp.repeat(jnp.asarray(qs), index.n, 0),
        jnp.tile(jnp.asarray(store), (qs.shape[0], 1)), w,
    )).reshape(qs.shape[0], index.n)
    assert np.all(sb <= d * (1 + 1e-5) + 1e-5)


def test_sketch_segments_ragged_and_short():
    # ragged: L = 37, s = 16 -> segment sizes differ by one, cover L
    segs = sketch_segments(37, 16)
    sizes = [b - a for a, b in segs]
    assert len(segs) == 16 and sum(sizes) == 37
    assert segs[0][0] == 0 and segs[-1][1] == 37
    assert all(b > a for a, b in segs)
    assert set(sizes) <= {2, 3}
    # short store: s halves (power-of-two discipline) until it fits
    assert len(sketch_segments(8, 16)) == 8
    assert len(sketch_segments(1, 16)) == 1
    np.testing.assert_array_equal(
        np.array(sketch_segment_sizes(37, 16)), np.array(sizes, np.float32)
    )


def test_sketch_outward_rounding_cellwise(rng):
    # the load-bearing invariant, asserted directly: dequantised cells
    # always contain the true segment means
    store = _walks(rng, 16, 50)
    index = build_index(store, 5)
    segs = sketch_segments(50, index.sk_lo.shape[1])
    useg = np.stack([np.mean(np.array(index.upper)[:, a:b], axis=1)
                     for a, b in segs], axis=1)
    lseg = np.stack([np.mean(np.array(index.lower)[:, a:b], axis=1)
                     for a, b in segs], axis=1)
    scale = float(np.array(index.sk_scale))
    assert np.all(np.array(index.sk_hi, np.float32) * scale >= useg - 1e-6)
    assert np.all(np.array(index.sk_lo, np.float32) * scale <= lseg + 1e-6)


def test_sketch_zero_variance_store_sanitized(rng):
    # flat series survive sanitize=True; maxabs = 0 branch keeps the
    # scale finite and the bound well-defined (zeros against any query
    # inside the envelope)
    store = np.zeros((12, 32), np.float32)
    store[6:] = _walks(rng, 6, 32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        index = build_index(store, 4, sanitize=True, normalize=True)
    sb = np.array(_sketch_bound(index, np.array(index.series)))
    assert np.all(np.isfinite(sb)) and np.all(sb >= 0)
    assert float(np.array(index.sk_scale)) > 0


def test_sketch_store_size_budget(rng):
    # acceptance bar: <= 32 bytes/candidate at the default S = 16
    store = _walks(rng, 40, 256)
    index = build_index(store, 26)
    per_cand = (index.sk_lo.nbytes + index.sk_hi.nbytes) / index.n
    assert per_cand <= 32, per_cand
    assert index.sk_lo.dtype == jnp.int8 and index.sk_hi.dtype == jnp.int8


# ---------------------------------------------------------------------------
# kernel / reference parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Q,N,L", [(4, 40, 64), (1, 200, 37), (9, 129, 96)])
def test_sketch_kernel_matches_ref(rng, Q, N, L):
    store = _walks(rng, N, L)
    qs = _walks(rng, Q, L)
    index = build_index(store, max(1, L // 8))
    s = index.sk_lo.shape[1]
    qbar = sketch_query_means(jnp.asarray(qs), s)
    seg = sketch_segment_sizes(L, s)
    want = np.array(ref.sketch_bound_ref(
        qbar, index.sk_lo, index.sk_hi, index.sk_scale, seg))
    got = np.array(sketch_bound_op(
        qbar, index.sk_lo, index.sk_hi, index.sk_scale, seg))
    assert got.shape == (Q, N)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_sketch_kernel_tiling_is_result_invariant(rng):
    # candidate padding (N % 128 != 0) and multi-tile grids change
    # nothing but the launch geometry (up to XLA re-fusion across the
    # different grid compilations — the same 1-ulp drift the streaming
    # DTW tests document vs the jnp reference, hence rtol over bits)
    store = _walks(rng, 300, 64)
    qs = _walks(rng, 3, 64)
    index = build_index(store, 8)
    s = index.sk_lo.shape[1]
    qbar = sketch_query_means(jnp.asarray(qs), s)
    seg = sketch_segment_sizes(64, s)
    scale = jnp.asarray(index.sk_scale, jnp.float32)
    qsc = qbar / scale
    wseg = jnp.asarray(seg, jnp.float32) * scale * scale
    base = np.array(sketch_bound_pallas(
        qsc, index.sk_lo, index.sk_hi, wseg, interpret=True))
    for tc in (128, 256):
        np.testing.assert_allclose(
            np.array(sketch_bound_pallas(
                qsc, index.sk_lo, index.sk_hi, wseg, tile_c=tc,
                interpret=True)),
            base, rtol=1e-6,
        )


# ---------------------------------------------------------------------------
# the sketch tier and the store mask inside the cascade
# ---------------------------------------------------------------------------


def test_sketch_tier_zeros_without_features(rng):
    # an index built without a sketch must keep the tier valid (all-zero
    # bound) so cfg.use_sketch is safe on any index
    store = _walks(rng, 16, 32)
    index = build_index(store, 4, sketch=None)
    cfg = CascadeConfig(w=4, use_sketch=True)
    t = get_tier("sketch").fn(jnp.asarray(store[:3]), index, cfg)
    np.testing.assert_array_equal(np.array(t), 0.0)


@pytest.mark.parametrize("wsel", ["0", "1", "L/4", "L"])
def test_masked_search_bit_equal_and_no_extra_dtw(rng, wsel):
    """The PR's acceptance property: neighbours bit-equal to brute force
    for arbitrary queries, and on the calibration distribution (the LOO
    sample the mask and plan were derived from) per-query n_dtw never
    exceeds the sketchless default plan's."""
    N, L, k = 96, 64, 3
    w = {"0": 0, "1": 1, "L/4": L // 4, "L": L}[wsel]
    store = _walks(rng, N, L)
    cfg = EngineConfig(cascade=CascadeConfig(w=w, use_sketch=True), k=k)
    plan_cache_clear()
    index = build_index(store, w, calibrate=cfg, mask=True)
    assert index.live is not None and bool(jnp.any(index.live))

    # arbitrary out-of-sample queries: exactness only
    qs = jnp.asarray(_walks(rng, 5, L))
    res = nn_search(index, qs, cfg)
    bd, _ = brute_force(index, qs, w, k=k)
    np.testing.assert_allclose(np.sort(np.array(res.dists), 1),
                               np.sort(np.array(bd), 1),
                               rtol=1e-5, atol=1e-5)

    # calibration-sample LOO queries: exact AND never more DTW
    pick = calibration_sample(N, 8)
    qs2 = jnp.asarray(store[pick])
    ex = jnp.asarray(pick, jnp.int32)
    res2 = nn_search(index, qs2, cfg, exclude=ex)
    bd2, _ = brute_force(index, qs2, w, k=k, exclude=ex)
    np.testing.assert_allclose(np.sort(np.array(res2.dists), 1),
                               np.sort(np.array(bd2), 1),
                               rtol=1e-5, atol=1e-5)
    base_cfg = EngineConfig(cascade=CascadeConfig(w=w), k=k)
    index0 = build_index(store, w, sketch=None)
    res0 = nn_search(index0, qs2, base_cfg, exclude=ex)
    assert np.all(np.array(res2.n_dtw) <= np.array(res0.n_dtw)), (
        np.array(res2.n_dtw), np.array(res0.n_dtw))
    plan_cache_clear()


def test_masked_search_skewed_store(rng):
    """Skewed store with planted outliers: rows far from *every*
    calibration query's neighbourhood go dead (their sketch bound clears
    2x every sampled tau), the search stays exact anyway, and the
    calibration queries pay no extra DTW.  Note the mask's any-query
    semantics mean a *cluster* can never kill itself — its own rows are
    each other's LOO neighbours — so dead candidates are genuinely
    unreachable ones, not merely far-from-one-query ones."""
    L, N, w, k = 64, 128, 12, 2
    store = rng.normal(size=(N, L)).astype(np.float32)
    pick = calibration_sample(N, 8)
    # plant outliers off the calibration stride: no sampled query sits
    # near them, and every sampled query's tau stays cluster-sized
    out_rows = np.array([5, 40, 70, 100])
    assert not np.intersect1d(out_rows, pick).size
    store[out_rows] += 50.0
    cfg = EngineConfig(cascade=CascadeConfig(w=w, use_sketch=True), k=k)
    plan_cache_clear()
    index = build_index(store, w, calibrate=cfg, mask=True)
    live = np.array(index.live)
    assert not live[out_rows].any(), "planted outliers survived the mask"
    assert live.mean() > 0.5, "mask over-killed the cluster"
    qs = jnp.asarray(store[pick])
    ex = jnp.asarray(pick, jnp.int32)
    res = nn_search(index, qs, cfg, exclude=ex)
    bd, _ = brute_force(index, qs, w, k=k, exclude=ex)
    np.testing.assert_allclose(np.sort(np.array(res.dists), 1),
                               np.sort(np.array(bd), 1),
                               rtol=1e-4, atol=1e-5)
    index0 = build_index(store, w, sketch=None)
    res0 = nn_search(index0, qs, EngineConfig(
        cascade=CascadeConfig(w=w), k=k), exclude=ex)
    assert np.all(np.array(res.n_dtw) <= np.array(res0.n_dtw))
    plan_cache_clear()


def test_mask_keeps_cheap_bound_on_dead_candidates(rng):
    # a dead candidate's running bound must stay finite (kim/sketch score
    # everyone) — the mask only withholds *refinement*, never the bound
    N, L, w, k = 64, 48, 6, 2
    store = _walks(rng, N, L)
    cfg = EngineConfig(cascade=CascadeConfig(w=w, use_sketch=True), k=k)
    plan_cache_clear()
    index = build_index(store, w, calibrate=cfg, mask=True)
    if not bool(jnp.all(index.live)):
        qs = jnp.asarray(_walks(rng, 3, L))
        cres = run_plan(qs, index, cfg.cascade, k=k)
        dead = ~np.array(index.live)
        assert np.all(np.isfinite(np.array(cres.lb)[:, dead]))
    plan_cache_clear()


def test_sketch_tier_first_in_default_plan(rng):
    cfg = CascadeConfig(w=4, use_sketch=True)
    plan = default_plan(cfg)
    assert plan.tiers[0].name == "sketch"
    assert plan.tiers[0].cost == "O(S)"
    assert default_plan(CascadeConfig(w=4)).tiers[0].name != "sketch"


# ---------------------------------------------------------------------------
# LB_Improved (Lemire, arXiv:0811.3301) as an optional pairwise tier
# ---------------------------------------------------------------------------


def test_lb_improved_tier_admissible_and_pluggable(rng):
    import dataclasses

    N, L, w, k = 48, 40, 5, 2
    store = _walks(rng, N, L)
    qs = jnp.asarray(_walks(rng, 4, L))
    index = build_index(store, w)
    cfg = CascadeConfig(w=w)
    tier = get_tier("lb_improved")
    assert tier.scope == "pairwise" and tier.cost == "O(L)"
    # admissible: the two-pass bound never exceeds DTW on packed pairs
    P = 16
    qrows = jnp.repeat(qs[:1], P, axis=0)
    crows = index.series[:P]
    out = np.array(tier.fn(qrows, crows, index.upper[:P],
                           index.lower[:P], cfg))
    d = np.array(ref.dtw_band_ref(qrows, crows, w))
    assert np.all(out <= d * (1 + 1e-5) + 1e-5)
    # first-pass dominance: LB_Improved >= LB_Keogh by construction
    first = np.array(jnp.stack([
        lb_keogh_env(qrows[i], index.upper[i], index.lower[i])
        for i in range(P)
    ]))
    assert np.all(out >= first - 1e-5)
    # live masking: dead slots return the scatter-max identity
    live = jnp.arange(P) % 2 == 0
    masked = np.array(tier.fn(qrows, crows, index.upper[:P],
                              index.lower[:P], cfg, live=live))
    assert np.all(np.isneginf(masked[1::2])) and np.all(
        masked[::2] == out[::2])
    # pluggable: swapping it in for the enhanced pairwise tier stays exact
    base = default_plan(cfg)
    tiers = tuple(t if t.scope != "pairwise" else tier for t in base.tiers)
    plan = dataclasses.replace(base, tiers=tiers)
    ecfg = EngineConfig(cascade=cfg, k=k, auto_plan=False)
    res = nn_search(index, qs, ecfg, plan=plan)
    bd, _ = brute_force(index, qs, w, k=k)
    np.testing.assert_allclose(np.sort(np.array(res.dists), 1),
                               np.sort(np.array(bd), 1),
                               rtol=1e-5, atol=1e-5)
