"""Envelope correctness: prefix-doubling vs the windowed-min/max oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import envelope, envelope_naive, oracle

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


@pytest.mark.parametrize("L,w", [(8, 1), (16, 0), (16, 16), (33, 7), (100, 99), (5, 2)])
def test_envelope_matches_oracle(rng, L, w):
    b = rng.normal(size=L).astype(np.float32)
    u, lo = envelope(jnp.array(b), w)
    uo, loo = oracle.envelope(b, w)
    assert np.allclose(np.array(u), uo)
    assert np.allclose(np.array(lo), loo)


@given(L=st.integers(2, 64), w=st.integers(0, 64), seed=st.integers(0, 2**31 - 1))
def test_envelope_property(L, w, seed):
    rng = np.random.default_rng(seed)
    b = rng.normal(size=L).astype(np.float32)
    u, lo = envelope(jnp.array(b), w)
    un, lon = envelope_naive(jnp.array(b), w)
    assert np.allclose(np.array(u), np.array(un))
    assert np.allclose(np.array(lo), np.array(lon))
    # envelopes bracket the series and widen with w
    assert np.all(np.array(u) >= b - 1e-6)
    assert np.all(np.array(lo) <= b + 1e-6)


def test_envelope_batched(rng):
    b = rng.normal(size=(7, 33)).astype(np.float32)
    u, lo = envelope(jnp.array(b), 5)
    for i in range(7):
        uo, loo = oracle.envelope(b[i], 5)
        assert np.allclose(np.array(u[i]), uo)
        assert np.allclose(np.array(lo[i]), loo)
