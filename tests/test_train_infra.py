"""Training-substrate tests: optimizers, accumulation, compression,
checkpointing (atomicity, restore, retention), elasticity plan."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import CompressionConfig, compress_grads, plan_mesh
from repro.distributed.elastic import Heartbeat
from repro.train import (
    OptConfig,
    latest_step,
    opt_init,
    opt_update,
    restore_checkpoint,
    save_checkpoint,
)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_minimises_quadratic(name):
    cfg = OptConfig(name=name, lr=0.1, weight_decay=0.0, warmup=1)
    target = jnp.array([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2))}
    state = opt_init(params, cfg)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for step in range(200):
        g = jax.grad(loss)(params)
        params, state = opt_update(params, g, state, cfg, jnp.int32(step))
    assert float(loss(params)) < 1e-2, name


def test_grad_clipping():
    from repro.train.optimizer import clip_by_global_norm
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert np.isclose(
        float(jnp.sqrt(jnp.sum(clipped["a"] ** 2))), 1.0, rtol=1e-5
    )


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_invariant(rng):
    cfg = CompressionConfig(bits=8, min_size=16)
    g = {"w": jnp.array(rng.normal(size=(64, 64)).astype(np.float32))}
    err = {"w": jnp.zeros((64, 64))}
    comp, new_err = compress_grads(g, err, cfg)
    # compressed + error == original (+ previous error): nothing is lost
    np.testing.assert_allclose(
        np.array(comp["w"] + new_err["w"]), np.array(g["w"]), rtol=1e-5, atol=1e-6
    )
    # 8-bit quantisation error is bounded by scale/2
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(new_err["w"]))) <= scale * 0.5 + 1e-6


def test_compression_small_leaves_passthrough(rng):
    cfg = CompressionConfig(bits=8, min_size=1 << 20)
    g = {"w": jnp.array(rng.normal(size=(8, 8)).astype(np.float32))}
    comp, err = compress_grads(g, {"w": jnp.zeros((8, 8))}, cfg)
    np.testing.assert_allclose(np.array(comp["w"]), np.array(g["w"]))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(7)}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, state, extra={"data_cursor": 123})
    like = jax.tree.map(jnp.zeros_like, state)
    restored, extra = restore_checkpoint(d, like)
    np.testing.assert_allclose(np.array(restored["params"]["w"]),
                               np.arange(6.0).reshape(2, 3))
    assert int(restored["step"]) == 7
    assert extra["data_cursor"] == 123


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"x": jnp.ones(3)})
    assert not any(f.endswith(".tmp") for f in os.listdir(d))
    assert latest_step(d) == 1


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, {"x": jnp.full(2, float(s))}, keep=3)
    steps = sorted(int(f.split("_")[1]) for f in os.listdir(d))
    assert steps == [3, 4, 5]
    assert latest_step(d) == 5


def test_checkpoint_restore_specific_step(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2):
        save_checkpoint(d, s, {"x": jnp.full(2, float(s))}, keep=5)
    restored, _ = restore_checkpoint(d, {"x": jnp.zeros(2)}, step=1)
    np.testing.assert_allclose(np.array(restored["x"]), [1.0, 1.0])


# ---------------------------------------------------------------------------
# elasticity / straggler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,tp", [(512, 16), (256, 16), (192, 16), (96, 16), (7, 16)])
def test_plan_mesh_always_valid(n, tp):
    plan = plan_mesh(n, preferred_tp=tp)
    total = 1
    for s in plan.shape:
        total *= s
    assert total <= n
    assert plan.shape[-1] <= tp


def test_plan_mesh_multi_pod():
    plan = plan_mesh(512, pods=2)
    assert plan.axes == ("pod", "data", "model")
    assert plan.shape == (2, 16, 16)


def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"), host_id=3)
    assert hb.is_straggler(0.001)        # no beat yet
    hb.beat(step=10)
    assert not hb.is_straggler(60.0)
    assert hb.age() is not None and hb.age() < 5.0
