"""Distributed tests that need >1 device: run in subprocesses with
XLA_FLAGS host-device counts (the main pytest process must keep the real
single-device view for everything else)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_search_exact():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index)
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
ds = make_dataset(n_classes=3, n_train_per_class=32, n_test_per_class=8,
                  length=64, seed=5)
idx = build_index(ds.x_train, 12, ds.y_train)
cfg = EngineConfig(cascade=CascadeConfig(w=12, v=4, candidate_chunk=32,
                                         use_pallas=False), verify_chunk=8, k=2)
sidx = shard_index(mesh, idx, ("data",))
step = make_distributed_search(mesh, cfg, data_axes=("data",), query_axis="model")
d, i, ndtw = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                  sidx.kim, sidx.kim_ok, jnp.asarray(ds.x_test))
bd, _ = brute_force(idx, ds.x_test, 12, k=2, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4), "distributed != brute force"
print("OK")
""")


def test_distributed_global_budget_skewed_shards():
    """The global survivor budget: all shards exact on a store whose
    near-neighbour mass lives entirely in shard 0, and the allocation
    actually skews (shard 0 gets more than the uniform share, the far
    shards drop toward the floor)."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index)
from repro.search.distributed import global_budget_limit_fn
from repro.distributed.sharding import shard_map_compat
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(7)
Q, L, N, w, k = 8, 64, 128, 12, 2
queries = rng.normal(size=(Q, L)).astype(np.float32)
near = np.repeat(queries, 4, axis=0) + 0.05 * rng.normal(size=(Q*4, L)).astype(np.float32)
far = 5.0 + rng.normal(size=(N - Q*4, L)).astype(np.float32)
series = np.concatenate([near, far], axis=0).astype(np.float32)
idx = build_index(series, w)
cfg = EngineConfig(cascade=CascadeConfig(w=w, v=4, candidate_chunk=32,
                                         use_pallas=False, survivor_budget=8),
                   verify_chunk=8, k=k)
sidx = shard_index(mesh, idx, ("data",))
step = make_distributed_search(mesh, cfg, data_axes=("data",),
                               query_axis="model", global_budget=True)
d, i, ndtw = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                  sidx.kim, sidx.kim_ok, jnp.asarray(queries))
bd, _ = brute_force(idx, queries, w, k=k, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4), "global budget != brute force"
# probe the allocation itself: shard 0 (all the near mass) must be granted
# more packed-refine slots than the far shards
limit_fn = global_budget_limit_fn(("data",))
def probe(series, q):
    # squared-Euclidean distance as a stand-in cheap tier: the probe only
    # exercises the allocation mechanics, which need some per-pair proxy
    lb01 = jnp.sum((q[:, None, :] - series[None, :, :]) ** 2, axis=-1)
    return limit_fn(lb01, 8, k)[None]
probe_fn = shard_map_compat(probe, mesh=mesh,
                            in_specs=(P(("data",), None), P(None, None)),
                            out_specs=P(("data",), None))
limits = np.array(probe_fn(sidx.series, jnp.asarray(queries)))   # (4, Q)
assert limits[0].mean() > 8, f"skewed shard not over-allocated: {limits}"
assert limits[1:].mean() < 8, f"far shards not under-allocated: {limits}"
print("OK", limits.mean(axis=1))
""")


def test_distributed_calibrated_plan_exact_and_committed():
    """Distributed calibrate-then-commit: the shard-local TierStats are
    psum/pmax-merged over the mesh, the host derives one global plan, and
    the committed step stays exact vs single-device brute force on a
    skewed store — with the planner's refine limit composed into the
    global-budget allocation."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index,
                          calibrate_distributed_plan)
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(7)
Q, L, N, w, k = 8, 64, 128, 12, 2
queries = rng.normal(size=(Q, L)).astype(np.float32)
near = np.repeat(queries, 4, axis=0) + 0.05 * rng.normal(size=(Q*4, L)).astype(np.float32)
far = 5.0 + rng.normal(size=(N - Q*4, L)).astype(np.float32)
series = np.concatenate([near, far], axis=0).astype(np.float32)
idx = build_index(series, w)
cfg = EngineConfig(cascade=CascadeConfig(w=w, v=4, candidate_chunk=32,
                                         use_pallas=False, survivor_budget=8),
                   verify_chunk=8, k=k)
sidx = shard_index(mesh, idx, ("data",))
qj = jnp.asarray(queries)
dec = calibrate_distributed_plan(
    mesh, cfg, sidx.series, sidx.labels, sidx.upper, sidx.lower,
    sidx.kim, sidx.kim_ok, qj, data_axes=("data",), query_axis="model")
# the calibrated compaction still carries the global-budget policy
assert dec.plan.compaction.limit_fn is not None, "lost the global budget"
step = make_distributed_search(mesh, cfg, data_axes=("data",),
                               query_axis="model", plan=dec.plan)
d, i, ndtw = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                  sidx.kim, sidx.kim_ok, qj)
bd, _ = brute_force(idx, queries, w, k=k, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4), "calibrated plan != brute force"
# the default-plan step on the same store: the committed plan may not
# verify more (conservative profile: only measured-idle work was cut)
step0 = make_distributed_search(mesh, cfg, data_axes=("data",),
                                query_axis="model")
d0, i0, ndtw0 = step0(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                      sidx.kim, sidx.kim_ok, qj)
assert np.all(np.array(ndtw) <= np.array(ndtw0)), (np.array(ndtw), np.array(ndtw0))
print("OK", dec.summary())
""")


def test_distributed_sketch_masked_step_exact():
    """The sketch store crosses the mesh: sk_lo/sk_hi row-shard, sk_scale
    replicates, the store mask vec-shards — and the committed sketch step
    is exact vs single-device brute force while verifying no more than
    the sketchless step on the calibration queries (each shard masks only
    its own rows, so the top-k merge semantics are untouched)."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index)
from repro.search.planner import calibration_sample
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(7)
L, N, w, k = 64, 128, 12, 2
series = np.cumsum(rng.normal(size=(N, L)), axis=1).astype(np.float32)
cfg = EngineConfig(cascade=CascadeConfig(w=w, v=4, candidate_chunk=32,
                                         use_pallas=False, use_sketch=True),
                   verify_chunk=8, k=k)
idx = build_index(series, w, calibrate=cfg, mask=True)
assert idx.sk_lo is not None and idx.live is not None
sidx = shard_index(mesh, idx, ("data",))
assert sidx.sk_lo is not None and sidx.live is not None
pick = calibration_sample(N, 8)
qj = jnp.asarray(series[pick])
step = make_distributed_search(mesh, cfg, data_axes=("data",),
                               query_axis="model", with_sketch=True)
d, i, ndtw = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                  sidx.kim, sidx.kim_ok, qj,
                  sidx.sk_lo, sidx.sk_hi, sidx.sk_scale, sidx.live)
bd, _ = brute_force(idx, series[pick], w, k=k, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4), "sketch step != brute force"
# sketchless baseline on the unchanged 7-leaf contract
cfg0 = EngineConfig(cascade=CascadeConfig(w=w, v=4, candidate_chunk=32,
                                          use_pallas=False),
                    verify_chunk=8, k=k)
idx0 = build_index(series, w, sketch=None)
sidx0 = shard_index(mesh, idx0, ("data",))
step0 = make_distributed_search(mesh, cfg0, data_axes=("data",),
                                query_axis="model")
d0, i0, ndtw0 = step0(sidx0.series, sidx0.labels, sidx0.upper, sidx0.lower,
                      sidx0.kim, sidx0.kim_ok, qj)
assert np.allclose(np.array(d0), np.array(bd), rtol=1e-4)
assert np.all(np.array(ndtw) <= np.array(ndtw0)), (np.array(ndtw), np.array(ndtw0))
print("OK", int(np.array(ndtw).sum()), "<=", int(np.array(ndtw0).sum()))
""")


def test_preflight_detects_jit_shard_map_miscompile():
    """The promoted form of the old strict-xfail ``jit(shard_map(while))``
    pin: ``preflight_shard_map`` must *agree with reality* — its verdict
    has to match whether a raw ``jax.jit(step)`` of the pinned
    miscompiling mesh/shape (4, 2), N=256, L=128, k=3 is exact — and
    ``make_distributed_search(jit="auto")`` must serve exact results
    either way, warning exactly once per process when it declines the
    jit.  On jax 0.4.x this proves detection (verdict False, unjitted
    path selected); on a fixed jax (>= 0.6, jax.shard_map + vma checks)
    it passes with verdict True and no warning — the XPASS analogue,
    with the auto path silently re-gaining the jit."""
    _run("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index,
                          preflight_shard_map, GuardWarning)
from repro.search import guards as _g
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))

verdict = preflight_shard_map(mesh, data_axes=("data",), query_axis="model")

ds = make_dataset(n_classes=4, n_train_per_class=64, n_test_per_class=1,
                  length=128, seed=3)
idx = build_index(ds.x_train, 16, ds.y_train)   # N = 256, L = 128
cfg = EngineConfig(cascade=CascadeConfig(w=16, v=4, candidate_chunk=64,
                                         use_pallas=False), verify_chunk=8, k=3)
sidx = shard_index(mesh, idx, ("data",))
q = jnp.asarray(ds.x_test)
bd, _ = brute_force(idx, ds.x_test, 16, k=3, use_pallas=False)

# ground truth: is the raw jitted step exact on the pinned repro shape?
raw = make_distributed_search(mesh, cfg, data_axes=("data",),
                              query_axis="model", jit=False)
dj, _, _ = jax.jit(raw)(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                        sidx.kim, sidx.kim_ok, q)
jit_exact = bool(np.allclose(np.array(dj), np.array(bd), rtol=1e-4))
assert verdict == jit_exact, (
    f"preflight verdict {verdict} disagrees with reality {jit_exact}")

# the auto path must be exact regardless of the verdict, and must warn
# exactly once per process when it declines the jit
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    step = make_distributed_search(mesh, cfg, data_axes=("data",),
                                   query_axis="model", jit="auto")
    step2 = make_distributed_search(mesh, cfg, data_axes=("data",),
                                    query_axis="model", jit="auto")
gw = [x for x in w if issubclass(x.category, GuardWarning)]
assert len(gw) == (0 if verdict else 1), gw
assert _g.warn_count("jit_shard_map_while") == (0 if verdict else 2)
d, _, _ = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
               sidx.kim, sidx.kim_ok, q)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4), (
    "auto path dropped candidates")
print("OK verdict =", verdict, "| jax", jax.__version__)
""")


def test_distributed_search_multipod_axes():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index)
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
ds = make_dataset(n_classes=2, n_train_per_class=16, n_test_per_class=4,
                  length=32, seed=9)
idx = build_index(ds.x_train, 8, ds.y_train)
cfg = EngineConfig(cascade=CascadeConfig(w=8, v=4, candidate_chunk=16,
                                         use_pallas=False), verify_chunk=4, k=1)
sidx = shard_index(mesh, idx, ("pod", "data"))
step = make_distributed_search(mesh, cfg, data_axes=("pod", "data"),
                               query_axis="model")
d, i, n = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
               sidx.kim, sidx.kim_ok, jnp.asarray(ds.x_test))
bd, _ = brute_force(idx, ds.x_test, 8, k=1, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4)
print("OK")
""")


def test_sharded_train_step_runs():
    """A reduced model trains under a real (data, model) mesh with the
    production sharding rules; loss finite, params stay sharded."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import ARCHS, reduced
from repro.distributed.sharding import AxisRules, param_shardings
from repro.models.model import LM
from repro.train import OptConfig, init_state, make_train_step
import dataclasses
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((2, 2), ("data", "model"))
rules = AxisRules()
r = reduced(ARCHS["qwen2-moe-a2.7b"])
r = dataclasses.replace(r, n_experts=8, top_k=2)
model = LM(cfg=r, mesh=mesh, dp_axes=("data",))
opt = OptConfig(lr=1e-3, warmup=1)
state = init_state(model, jax.random.PRNGKey(0), opt)
pspecs = param_shardings(r, mesh, rules, state.params)
state = dataclasses.replace(state, params=jax.device_put(state.params, pspecs))
step = jax.jit(make_train_step(model, opt))
B, S = 4, 16
batch = {
  "tokens": jax.device_put(jnp.zeros((B, S), jnp.int32),
                           NamedSharding(mesh, P("data", None))),
  "labels": jax.device_put(jnp.ones((B, S), jnp.int32),
                           NamedSharding(mesh, P("data", None))),
}
for _ in range(2):
    state, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
print("OK", float(m["loss"]))
""")


def test_elastic_restart_reshard():
    """Save under a 4-device mesh, restore under a 2-device mesh."""
    _run("""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import save_checkpoint, restore_checkpoint
devs = jax.devices()
from repro.launch.mesh import make_host_mesh
m4 = make_host_mesh((4,), ("data",))
m2 = make_host_mesh((2,), ("data",))
x = jax.device_put(jnp.arange(16.0).reshape(8, 2),
                   NamedSharding(m4, P("data", None)))
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 3, {"x": x})
    like = jax.ShapeDtypeStruct((8, 2), jnp.float32)
    restored, _ = restore_checkpoint(
        d, {"x": jnp.zeros((8, 2))},
        shardings={"x": NamedSharding(m2, P("data", None))})
    assert np.allclose(np.array(restored["x"]), np.arange(16.0).reshape(8, 2))
    assert restored["x"].sharding.mesh.shape["data"] == 2
print("OK")
""")
