"""Distributed tests that need >1 device: run in subprocesses with
XLA_FLAGS host-device counts (the main pytest process must keep the real
single-device view for everything else)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_search_exact():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index)
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
ds = make_dataset(n_classes=3, n_train_per_class=32, n_test_per_class=8,
                  length=64, seed=5)
idx = build_index(ds.x_train, 12, ds.y_train)
cfg = EngineConfig(cascade=CascadeConfig(w=12, v=4, candidate_chunk=32,
                                         use_pallas=False), verify_chunk=8, k=2)
sidx = shard_index(mesh, idx, ("data",))
step = make_distributed_search(mesh, cfg, data_axes=("data",), query_axis="model")
d, i, ndtw = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                  sidx.kim, sidx.kim_ok, jnp.asarray(ds.x_test))
bd, _ = brute_force(idx, ds.x_test, 12, k=2, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4), "distributed != brute force"
print("OK")
""")


def test_distributed_search_multipod_axes():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.data import make_dataset
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index)
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
ds = make_dataset(n_classes=2, n_train_per_class=16, n_test_per_class=4,
                  length=32, seed=9)
idx = build_index(ds.x_train, 8, ds.y_train)
cfg = EngineConfig(cascade=CascadeConfig(w=8, v=4, candidate_chunk=16,
                                         use_pallas=False), verify_chunk=4, k=1)
sidx = shard_index(mesh, idx, ("pod", "data"))
step = make_distributed_search(mesh, cfg, data_axes=("pod", "data"),
                               query_axis="model")
d, i, n = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
               sidx.kim, sidx.kim_ok, jnp.asarray(ds.x_test))
bd, _ = brute_force(idx, ds.x_test, 8, k=1, use_pallas=False)
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4)
print("OK")
""")


def test_sharded_train_step_runs():
    """A reduced model trains under a real (data, model) mesh with the
    production sharding rules; loss finite, params stay sharded."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import ARCHS, reduced
from repro.distributed.sharding import AxisRules, param_shardings
from repro.models.model import LM
from repro.train import OptConfig, init_state, make_train_step
import dataclasses
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((2, 2), ("data", "model"))
rules = AxisRules()
r = reduced(ARCHS["qwen2-moe-a2.7b"])
r = dataclasses.replace(r, n_experts=8, top_k=2)
model = LM(cfg=r, mesh=mesh, dp_axes=("data",))
opt = OptConfig(lr=1e-3, warmup=1)
state = init_state(model, jax.random.PRNGKey(0), opt)
pspecs = param_shardings(r, mesh, rules, state.params)
state = dataclasses.replace(state, params=jax.device_put(state.params, pspecs))
step = jax.jit(make_train_step(model, opt))
B, S = 4, 16
batch = {
  "tokens": jax.device_put(jnp.zeros((B, S), jnp.int32),
                           NamedSharding(mesh, P("data", None))),
  "labels": jax.device_put(jnp.ones((B, S), jnp.int32),
                           NamedSharding(mesh, P("data", None))),
}
for _ in range(2):
    state, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
print("OK", float(m["loss"]))
""")


def test_elastic_restart_reshard():
    """Save under a 4-device mesh, restore under a 2-device mesh."""
    _run("""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import save_checkpoint, restore_checkpoint
devs = jax.devices()
from repro.launch.mesh import make_host_mesh
m4 = make_host_mesh((4,), ("data",))
m2 = make_host_mesh((2,), ("data",))
x = jax.device_put(jnp.arange(16.0).reshape(8, 2),
                   NamedSharding(m4, P("data", None)))
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 3, {"x": x})
    like = jax.ShapeDtypeStruct((8, 2), jnp.float32)
    restored, _ = restore_checkpoint(
        d, {"x": jnp.zeros((8, 2))},
        shardings={"x": NamedSharding(m2, P("data", None))})
    assert np.allclose(np.array(restored["x"]), np.arange(16.0).reshape(8, 2))
    assert restored["x"].sharding.mesh.shape["data"] == 2
print("OK")
""")
