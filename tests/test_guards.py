"""Exactness guards + fault injection: every injector trips its guard.

The contract under test (search/guards.py + testing/faults.py):

  * clean data: guards are invisible — bit-equal results, zero counters;
  * every deterministic injector trips exactly the guard built for it;
  * tripped trigger-guards degrade: the batch is re-served via reference
    brute force (bounds untrusted, jnp kernels) and the result is
    bit-equal to an independent brute-force run (the ``dtw_out`` fault
    seam lives in kernels/ops.py only, so the fallback dodges injected
    kernel faults by construction);
  * non-finite faults are *contained* (counted, gated, results exact)
    without tripping the degradation ladder — except NaN verification
    values, whose +inf gate may exclude a true neighbour and therefore
    must degrade;
  * input hygiene at the build_index/nn_search boundary rejects (or,
    with ``sanitize=True``, masks and reports) NaN/Inf and zero-variance
    series before they reach z-normalisation.

CI runs this file twice: once normally and once with
``REPRO_FORCE_GUARDS=1`` so a refactor cannot silently disarm the
default-on wiring.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.search import (
    CascadeConfig,
    EngineConfig,
    GuardConfig,
    GuardReport,
    GuardWarning,
    brute_force,
    build_index,
    nn_search,
    preflight_engine,
)
from repro.search import guards as guards_mod
from repro.search.planner import PlannerConfig, calibrate_plan
from repro.testing import faults

W, K = 4, 2


def _store(n=48, length=24, n_q=6, seed=7):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, length)).astype(np.float32)
    q = rng.normal(size=(n_q, length)).astype(np.float32)
    return x, q


def _cfg(use_pallas=False, guards=None, **kw):
    return EngineConfig(
        cascade=CascadeConfig(w=W, v=4, candidate_chunk=16,
                              use_pallas=use_pallas),
        verify_chunk=8, k=K, auto_plan=False, guards=guards, **kw,
    )


def _search(idx, q, cfg):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res, rep = nn_search(idx, q, cfg, with_guards=True)
    gw = [x for x in w if issubclass(x.category, GuardWarning)]
    return res, rep, gw


@pytest.fixture()
def store():
    x, q = _store()
    idx = build_index(x, W)
    bd, bi = brute_force(idx, q, W, K, use_pallas=False)
    return idx, q, np.asarray(bd), np.asarray(bi)


@pytest.fixture(scope="module", autouse=True)
def _drop_jit_caches():
    # this module compiles dozens of one-off fault-injected engine
    # variants (each installed hook is a distinct trace); leaving them
    # in jax's global jit cache has crashed XLA's CPU compiler on later
    # heavy compiles in the same process (test_streaming's L=16384
    # stream grid) — clear them on the way out
    yield
    jax.clear_caches()


# ---------------------------------------------------------------------------
# clean path: guards are invisible
# ---------------------------------------------------------------------------


def test_clean_guarded_run_bit_equal_and_counters_zero(store):
    idx, q, bd, bi = store
    res_off = nn_search(idx, q, _cfg(guards=GuardConfig(enabled=False)))
    res_on, rep, gw = _search(idx, q, _cfg())
    assert np.array_equal(np.asarray(res_on.dists), np.asarray(res_off.dists))
    assert np.array_equal(np.asarray(res_on.idx), np.asarray(res_off.idx))
    assert np.array_equal(np.asarray(res_on.dists), bd)
    assert rep.ok() and rep.tripped() == ()
    for f in ("admiss_viol", "conserve_viol", "account_viol",
              "nonfinite_bounds", "nonfinite_dtw", "degraded"):
        assert float(np.asarray(getattr(rep, f))) == 0.0, f
    assert float(np.asarray(rep.admiss_checked)) > 0
    assert float(np.asarray(rep.conserve_checked)) > 0
    assert not gw


def test_clean_guarded_run_jit_clean(store):
    idx, q, bd, _ = store
    cfg = _cfg()

    @jax.jit
    def run(qq):
        res, rep = nn_search(idx, qq, cfg, with_guards=True)
        return res.dists, rep.to_vector()

    d, vec = run(jnp.asarray(q))
    assert np.array_equal(np.asarray(d), bd)
    rep = GuardReport.from_vector(vec)
    assert rep.ok()


def test_guard_report_vector_roundtrip_and_merge():
    import dataclasses

    rep = dataclasses.replace(
        GuardReport.zeros(),
        admiss_checked=jnp.float32(10.0), admiss_viol=jnp.float32(2.0),
        admiss_gap=jnp.float32(0.5), nonfinite_dtw=jnp.float32(3.0),
    )
    back = GuardReport.from_vector(rep.to_vector())
    for f in guards_mod._VEC_FIELDS:
        assert float(np.asarray(getattr(back, f))) == float(
            np.asarray(getattr(rep, f))), f
    merged = rep.merge(rep)
    assert float(np.asarray(merged.admiss_checked)) == 20.0
    assert float(np.asarray(merged.admiss_gap)) == 0.5   # max, not sum
    assert merged.tripped() == ("admiss_viol", "nonfinite_dtw")


def test_forced_guards_env(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_GUARDS", "1")
    g = guards_mod.resolve_guards(GuardConfig(enabled=False))
    assert g.enabled and g.admissibility and g.conservation
    monkeypatch.setenv("REPRO_FORCE_GUARDS", "0")
    assert not guards_mod.resolve_guards(GuardConfig(enabled=False)).enabled


# ---------------------------------------------------------------------------
# trigger guards: injector trips -> degradation restores bit-equality
# ---------------------------------------------------------------------------


def test_inadmissible_tier_trips_and_degrades(store):
    idx, q, bd, bi = store
    with faults.inadmissible_tier():
        res, rep, gw = _search(idx, q, _cfg())
    assert "admiss_viol" in rep.tripped()
    assert float(np.asarray(rep.degraded)) > 0
    assert len(gw) == 1
    assert np.array_equal(np.asarray(res.dists), bd)
    assert np.array_equal(np.asarray(res.idx), bi)


def test_corrupt_dtw_scale_trips_admissibility(store):
    # shrunk verification values fall below valid bounds; the rerun uses
    # the jnp reference kernels (no dtw_out seam) and restores exactness
    idx, q, bd, bi = store
    with faults.corrupt_dtw():
        res, rep, gw = _search(idx, q, _cfg(use_pallas=True))
    assert "admiss_viol" in rep.tripped()
    assert float(np.asarray(rep.degraded)) > 0
    assert np.array_equal(np.asarray(res.dists), bd)
    assert np.array_equal(np.asarray(res.idx), bi)


def test_corrupt_dtw_nan_trips_nonfinite_and_degrades(store):
    idx, q, bd, bi = store
    with faults.corrupt_dtw(value=np.nan):
        res, rep, gw = _search(idx, q, _cfg(use_pallas=True))
    assert "nonfinite_dtw" in rep.tripped()
    assert float(np.asarray(rep.nonfinite_dtw)) > 0
    assert float(np.asarray(rep.degraded)) > 0
    assert np.array_equal(np.asarray(res.dists), bd)
    assert np.array_equal(np.asarray(res.idx), bi)


def test_drop_compaction_candidates_trips_conservation(store):
    idx, q, bd, _ = store
    with faults.drop_compaction_candidates():
        res, rep, gw = _search(idx, q, _cfg())
    assert "conserve_viol" in rep.tripped()
    assert float(np.asarray(rep.degraded)) > 0
    assert np.array_equal(np.asarray(res.dists), bd)


def test_miscount_verifications_trips_accounting(store):
    idx, q, bd, _ = store
    with faults.miscount_verifications():
        res, rep, gw = _search(idx, q, _cfg())
    assert "account_viol" in rep.tripped()
    assert np.array_equal(np.asarray(res.dists), bd)


def test_inward_quantiser_trips_and_degrades():
    # build-time fault (like poison_envelopes): the corrupted sketch
    # store persists past the injector's scope, and the *search* against
    # it must trip the seed admissibility spot-check — the inverted
    # envelopes inflate the tier-(-1) bound above true near-neighbour
    # DTW distances — then degrade to reference brute force (which
    # never reads the sketch) bit-equally
    x, q = _store()
    with faults.inward_quantiser():
        bad = build_index(x, W)
    assert bad.sk_lo is not None
    cfg = EngineConfig(
        cascade=CascadeConfig(w=W, v=4, candidate_chunk=16,
                              use_pallas=False, use_sketch=True),
        verify_chunk=8, k=K, auto_plan=False,
    )
    bd, bi = brute_force(bad, q, W, K, use_pallas=False)
    res, rep, gw = _search(bad, q, cfg)
    assert "admiss_viol" in rep.tripped()
    assert float(np.asarray(rep.degraded)) > 0
    assert np.array_equal(np.asarray(res.dists), np.asarray(bd))
    assert np.array_equal(np.asarray(res.idx), np.asarray(bi))
    # the same store searched without the sketch tier is clean: the
    # fault lives in the sketch features alone
    res2, rep2, _ = _search(bad, q, _cfg())
    assert rep2.tripped() == ()
    assert np.array_equal(np.asarray(res2.dists), np.asarray(bd))


def test_degrade_false_reports_but_serves_raw(store, monkeypatch):
    # the env force overrides degrade=False by design — clear it so this
    # tests the config path, not the CI override
    monkeypatch.delenv("REPRO_FORCE_GUARDS", raising=False)
    idx, q, bd, _ = store
    with faults.inadmissible_tier():
        res, rep, gw = _search(
            idx, q, _cfg(guards=GuardConfig(degrade=False)))
    assert "admiss_viol" in rep.tripped()
    assert float(np.asarray(rep.degraded)) == 0.0
    assert not gw   # no rerun, no warning — caller opted to only observe


# ---------------------------------------------------------------------------
# containment guards: counted + gated, results stay exact, no trip
# ---------------------------------------------------------------------------


def test_poison_envelopes_contained(store):
    idx, q, bd, bi = store
    bad = faults.poison_envelopes(idx, rows=(0, 3, 5))
    res, rep, gw = _search(bad, q, _cfg())
    assert float(np.asarray(rep.nonfinite_bounds)) > 0
    assert rep.tripped() == ()
    assert np.array_equal(np.asarray(res.dists), bd)
    assert np.array_equal(np.asarray(res.idx), bi)


def test_nonfinite_tier_contained(store):
    idx, q, bd, _ = store
    with faults.nonfinite_tier():
        res, rep, gw = _search(idx, q, _cfg())
    assert float(np.asarray(rep.nonfinite_bounds)) > 0
    assert rep.tripped() == ()
    assert np.array_equal(np.asarray(res.dists), bd)


def test_corrupt_packed_rows_contained(store):
    idx, q, bd, _ = store
    with faults.corrupt_packed_rows():
        res, rep, gw = _search(idx, q, _cfg())
    assert float(np.asarray(rep.nonfinite_bounds)) > 0
    assert rep.tripped() == ()
    assert np.array_equal(np.asarray(res.dists), bd)


def test_gates_off_nan_bounds_would_poison(store):
    # the control experiment for the line-438 fix: with finite gates off
    # and a tier emitting NaN, the engine must NOT silently exclude the
    # poisoned candidates' true neighbours.  Gates-on is the default; we
    # only check the guarded path stays exact under the same fault above.
    idx, q, bd, _ = store
    with faults.nonfinite_tier():
        res, rep, gw = _search(idx, q, _cfg())
    assert np.array_equal(np.asarray(res.dists), bd)


# ---------------------------------------------------------------------------
# input hygiene (boundary)
# ---------------------------------------------------------------------------


def test_hygiene_build_index_rejects_nan():
    x, _ = _store()
    bad = faults.corrupt_series(x, rows=(1, 4), cols=(0, 3))
    with pytest.raises(ValueError, match="series"):
        build_index(bad, W)


def test_hygiene_build_index_sanitize_masks_and_warns():
    x, q = _store()
    bad = faults.corrupt_series(x, rows=(1,), cols=(0, 3), value=np.inf)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        idx = build_index(bad, W, sanitize=True)
    assert any(issubclass(x.category, GuardWarning) for x in w)
    assert bool(np.all(np.isfinite(np.asarray(idx.series))))
    res, rep, _ = _search(idx, q, _cfg())
    assert bool(np.all(np.isfinite(np.asarray(res.dists))))


def test_hygiene_query_rejects_and_sanitizes(store):
    idx, q, bd, _ = store
    badq = faults.corrupt_series(q, rows=(0,), cols=(2,))
    with pytest.raises(ValueError, match="query"):
        nn_search(idx, badq, _cfg())
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res, rep = nn_search(idx, badq, _cfg(), with_guards=True,
                             sanitize=True)
    assert any(issubclass(x.category, GuardWarning) for x in w)
    assert float(np.asarray(rep.hygiene_values)) > 0
    # untouched queries still serve their exact neighbours
    assert np.array_equal(np.asarray(res.dists)[1:], bd[1:])


def test_hygiene_flat_series_under_normalize():
    x, _ = _store()
    x[2] = 1.5   # zero variance: z-norm would divide by ~0
    with pytest.raises(ValueError, match="zero-variance"):
        build_index(x, W, normalize=True)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        idx = build_index(x, W, normalize=True, sanitize=True)
    assert any(issubclass(c.category, GuardWarning) for c in w)
    assert bool(np.all(np.isfinite(np.asarray(idx.series))))


# ---------------------------------------------------------------------------
# preflight + planner fallback
# ---------------------------------------------------------------------------


def test_preflight_engine_ok_and_cached():
    guards_mod.preflight_clear()
    try:
        assert preflight_engine() is True
        assert preflight_engine() is True   # cache hit, no recompute
    finally:
        guards_mod.preflight_clear()


def test_build_index_preflight_flag():
    x, _ = _store(n=32, length=16)
    guards_mod.preflight_clear()
    try:
        build_index(x, W, preflight=True)
        assert ("engine", jax.__version__) in guards_mod._PREFLIGHT_CACHE
    finally:
        guards_mod.preflight_clear()


def test_calibrate_plan_falls_back_on_tripped_guard(store):
    idx, q, _, _ = store
    cfg = _cfg()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inadmissible_tier():
            dec = calibrate_plan(q, idx, cfg.cascade, K,
                                 pcfg=PlannerConfig())
    assert any(issubclass(x.category, GuardWarning) for x in w)
    # measurements under a tripped guard are untrusted: nothing dropped
    assert dec.dropped == ()


# ---------------------------------------------------------------------------
# injector harness hygiene
# ---------------------------------------------------------------------------


def test_inject_rejects_nested_same_seam():
    with faults.miscount_verifications():
        with pytest.raises(RuntimeError, match="already injected"):
            with faults.miscount_verifications():
                pass
    assert "engine_count" not in guards_mod._FAULT_HOOKS


def test_seams_empty_after_faults():
    x, q = _store(n=16, length=16, n_q=2)
    idx = build_index(x, W)
    with faults.drop_compaction_candidates():
        nn_search(idx, q, _cfg())
    assert guards_mod._FAULT_HOOKS == {}


# ---------------------------------------------------------------------------
# distributed: guard transport + shard dropout (subprocess, 8 host devices)
# ---------------------------------------------------------------------------


def _run_distributed(script: str, n_devices: int = 8) -> str:
    import os
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_DIST_PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.search import (build_index, brute_force, EngineConfig, CascadeConfig,
                          make_distributed_search, shard_index, GuardReport)
from repro.testing import faults
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(11)
X = rng.normal(size=(64, 32)).astype(np.float32)
q = rng.normal(size=(4, 32)).astype(np.float32)
idx = build_index(X, 8)
cfg = EngineConfig(cascade=CascadeConfig(w=8, v=4, candidate_chunk=16,
                                         use_pallas=False), verify_chunk=4, k=2)
sidx = shard_index(mesh, idx, ("data",))
step = make_distributed_search(mesh, cfg, data_axes=("data",),
                               query_axis="model", jit=False,
                               with_guards=True)
bd, _ = brute_force(idx, q, 8, k=2, use_pallas=False)
"""


def test_distributed_guard_vector_merged_clean():
    _run_distributed(_DIST_PRELUDE + """
d, i, n, gv = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                   sidx.kim, sidx.kim_ok, jnp.asarray(q))
assert np.allclose(np.array(d), np.array(bd), rtol=1e-4)
rep = GuardReport.from_vector(gv)
assert rep.ok(), rep.summary()
# psum actually merged across 8 shards: every shard checked something
assert float(np.asarray(rep.conserve_checked)) > 0
assert float(np.asarray(rep.admiss_checked)) > 0
print("OK", rep.summary())
""")


def test_distributed_shard_dropout_trips_conservation():
    _run_distributed(_DIST_PRELUDE + """
with faults.shard_dropout(shard=0):
    d, i, n, gv = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                       sidx.kim, sidx.kim_ok, jnp.asarray(q))
rep = GuardReport.from_vector(gv)
assert "conserve_viol" in rep.tripped(), rep.summary()
print("OK", rep.summary())
""")
