"""Tier-pipeline + bound-ordered-scheduler invariants.

The contract under test (search/pipeline.py, search/cascade.py,
search/engine.py, kernels/ops.py):
  * the verification schedule is a pair-packing permutation only:
    ``schedule="bound"`` returns results *bit-equal* to
    ``schedule="index"`` and to brute force, and never increases any
    query's ``n_dtw`` — across w in {0, 1, L/4, L}, k, chunkings, ragged
    survivor budgets, and leave-one-out exclusion;
  * the ``perm`` gather on the DTW ops is a semantic no-op;
  * plans are declarative: tiers can be registered, added, and reordered
    without touching the executor, and a custom tier that returns any
    valid lower bound keeps the engine exact;
  * the compaction ``limit_fn`` policy (the global-budget hook) trades
    bound tightness only — never exactness or bound validity;
  * the adaptive-budget memo keys on (index identity, k, w): changing any
    of them re-estimates instead of reusing a stale bucket.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import make_dataset
from repro.kernels import ops, ref
from repro.search import (
    BoundTier,
    CascadeConfig,
    Compaction,
    EngineConfig,
    VerificationPlan,
    bands_prefilter,
    brute_force,
    build_index,
    default_plan,
    get_tier,
    nn_search,
    register_tier,
    run_plan,
)
from repro.search import pipeline as pl

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

L_TEST = 48


def _setup(w=8, n_per=12, L=L_TEST, seed=0, k=1, chunk=16, verify=4, **ckw):
    ds = make_dataset(n_classes=3, n_train_per_class=n_per,
                      n_test_per_class=4, length=L, seed=seed)
    idx = build_index(ds.x_train, w, ds.y_train)
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, candidate_chunk=chunk, **ckw),
        verify_chunk=verify, k=k,
    )
    return ds, idx, cfg


# ---------------------------------------------------------------------------
# bound-ordered schedule: results bit-equal, n_dtw never worse
# ---------------------------------------------------------------------------

@given(
    w=st.sampled_from([0, 1, L_TEST // 4, L_TEST]),
    k=st.integers(1, 3),
    verify=st.integers(1, 9),
    budget=st.sampled_from([None, 1, 2, 5, 17]),
    seed=st.integers(0, 1000),
)
def test_bound_schedule_exact_and_no_more_dtw(w, k, verify, budget, seed):
    """For every (window, k, chunking, ragged budget, data): the
    bound-ordered scheduler is bit-equal to brute force and to the
    index-ordered scheduler, and per-query n_dtw never increases."""
    ds, idx, cfg = _setup(w=w, seed=seed, k=k, verify=verify,
                          survivor_budget=budget)
    res_b = nn_search(idx, ds.x_test, cfg,
                      plan=default_plan(cfg.cascade, schedule="bound"))
    res_i = nn_search(idx, ds.x_test, cfg,
                      plan=default_plan(cfg.cascade, schedule="index"))
    bd, _ = brute_force(idx, ds.x_test, w, k=k)
    np.testing.assert_array_equal(np.array(res_b.dists), np.array(bd))
    np.testing.assert_array_equal(np.array(res_b.dists), np.array(res_i.dists))
    np.testing.assert_array_equal(np.array(res_b.idx), np.array(res_i.idx))
    assert np.all(np.array(res_b.n_dtw) <= np.array(res_i.n_dtw))


def test_bound_schedule_with_exclude():
    ds, idx, cfg = _setup(k=2)
    q = ds.x_train[:6]
    ex = jnp.arange(6)
    res_b = nn_search(idx, q, cfg, exclude=ex,
                      plan=default_plan(cfg.cascade, schedule="bound"))
    res_i = nn_search(idx, q, cfg, exclude=ex,
                      plan=default_plan(cfg.cascade, schedule="index"))
    bd, _ = brute_force(idx, q, 8, k=2, exclude=ex)
    np.testing.assert_array_equal(np.array(res_b.dists), np.array(bd))
    np.testing.assert_array_equal(np.array(res_b.n_dtw), np.array(res_i.n_dtw))
    assert np.all(np.array(res_b.idx[:, 0]) != np.arange(6))


def test_default_plan_is_bound_scheduled():
    cfg = CascadeConfig(w=8)
    plan = default_plan(cfg)
    assert plan.schedule == "bound"
    assert [t.name for t in plan.tiers] == ["kim", "bands",
                                            "enhanced_pairwise"]
    assert [t.cost for t in plan.tiers] == ["O(1)", "O(V^2)", "O(L)"]
    # tier names round-trip through the registry
    for t in plan.tiers:
        assert get_tier(t.name).name == t.name


# ---------------------------------------------------------------------------
# the pair-packing permutation is a semantic no-op on the DTW ops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P,L,w", [(9, 33, 8), (16, 21, 5), (130, 17, 4)])
def test_dtw_perm_gather_is_noop(rng, P, L, w):
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut = jnp.array(np.where(np.arange(P) % 3 == 0,
                             plain * 0.5,
                             plain * 2.0 + 1.0).astype(np.float32))
    perm = jnp.array(rng.permutation(P))
    for fn in (ops.dtw_band_op, ref.dtw_band_ref):
        base = np.array(fn(a, b, w, cut))
        got = np.array(fn(a, b, w, cut, perm=perm))
        np.testing.assert_array_equal(got, base)
        # no cutoff: permutation of a cutoff-free batch
        np.testing.assert_array_equal(
            np.array(fn(a, b, w, perm=perm)), np.array(fn(a, b, w)),
        )
        # scalar cutoffs stay legal under perm (broadcast before gather)
        scal = float(plain.max() * 2 + 1)
        np.testing.assert_array_equal(
            np.array(fn(a, b, w, scal, perm=perm)),
            np.array(fn(a, b, w, scal)),
        )


# ---------------------------------------------------------------------------
# declarative plans: registration, reordering, custom tiers
# ---------------------------------------------------------------------------

def test_register_custom_tier_keeps_engine_exact():
    """The pipeline.py worked example: a second bands pass at V=2 slots in
    front of the V=4 tier as pure plan data, engine exactness untouched."""

    @register_tier("bands_v2_test")
    def bands_v2_tier() -> BoundTier:
        def fn(q, index, cfg):
            return bands_prefilter(q, index, dataclasses.replace(cfg, v=2))
        return BoundTier("bands_v2", cost="O(V^2)", scope="all_pairs", fn=fn)

    assert "bands_v2_test" in pl.registered_tiers()
    ds, idx, cfg = _setup(k=2)
    plan = default_plan(cfg.cascade)
    plan = dataclasses.replace(
        plan,
        tiers=(plan.tiers[0], get_tier("bands_v2_test"), *plan.tiers[1:]),
    )
    res = nn_search(idx, ds.x_test, cfg, plan=plan)
    bd, _ = brute_force(idx, ds.x_test, 8, k=2)
    np.testing.assert_array_equal(np.array(res.dists), np.array(bd))


def test_reordering_all_pairs_tiers_is_result_invariant():
    """Running max is commutative: kim->bands == bands->kim."""
    ds, idx, cfg = _setup()
    plan = default_plan(cfg.cascade)
    swapped = dataclasses.replace(
        plan, tiers=(plan.tiers[1], plan.tiers[0], plan.tiers[2])
    )
    q = jnp.asarray(ds.x_test)
    a = run_plan(q, idx, cfg.cascade, plan, k=1)
    b = run_plan(q, idx, cfg.cascade, swapped, k=1)
    np.testing.assert_array_equal(np.array(a.lb), np.array(b.lb))


def test_plan_validation():
    kim, bands, enh = (get_tier("kim"), get_tier("bands"),
                       get_tier("enhanced_pairwise"))
    with pytest.raises(ValueError, match="compaction point"):
        VerificationPlan(tiers=(kim, enh, bands))
    with pytest.raises(ValueError, match="schedule"):
        VerificationPlan(tiers=(kim, enh), schedule="random")
    with pytest.raises(ValueError, match="scope"):
        BoundTier("x", cost="O(1)", scope="rowwise", fn=lambda *a: None)
    with pytest.raises(KeyError, match="unknown tier"):
        get_tier("no_such_tier")
    # dense bounds have no compaction: pairwise tiers are rejected loudly
    # instead of silently dropped
    from repro.search import compute_bounds
    ds, idx, cfg = _setup()
    dense_cfg = dataclasses.replace(cfg.cascade, staged=False)
    with pytest.raises(ValueError, match="pairwise tiers"):
        compute_bounds(jnp.asarray(ds.x_test), idx, dense_cfg,
                       plan=VerificationPlan(tiers=(kim, enh)))


def test_unknown_schedule_vs_tiers_smoke():
    """A plan with no pairwise tier still seeds and stays exact (cheap
    tiers only — compaction is skipped entirely)."""
    ds, idx, cfg = _setup(k=2)
    plan = VerificationPlan(tiers=(get_tier("kim"), get_tier("bands")))
    res = nn_search(idx, ds.x_test, cfg, plan=plan)
    bd, _ = brute_force(idx, ds.x_test, 8, k=2)
    np.testing.assert_array_equal(np.array(res.dists), np.array(bd))


# ---------------------------------------------------------------------------
# schedule-aware pair-tile sizing: geometry only, results + n_dtw invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile", [None, 8, 16, 128])
def test_verify_tile_p_is_result_and_ndtw_invariant(tile):
    """The per-round pair-tile is packing geometry: any verify_tile_p
    (and the None policy default) gives bit-equal results and identical
    per-query n_dtw vs brute force and vs the kernel-default plan."""
    ds, idx, cfg = _setup(k=2, verify=6)
    base = nn_search(idx, ds.x_test, cfg,
                     plan=default_plan(cfg.cascade, schedule="bound"))
    plan = dataclasses.replace(
        default_plan(cfg.cascade, schedule="bound"), verify_tile_p=tile)
    res = nn_search(idx, ds.x_test, cfg, plan=plan)
    bd, _ = brute_force(idx, ds.x_test, 8, k=2)
    np.testing.assert_array_equal(np.array(res.dists), np.array(bd))
    np.testing.assert_array_equal(np.array(res.dists), np.array(base.dists))
    np.testing.assert_array_equal(np.array(res.idx), np.array(base.idx))
    np.testing.assert_array_equal(np.array(res.n_dtw), np.array(base.n_dtw))


# ---------------------------------------------------------------------------
# compaction limit policy (the global-budget hook)
# ---------------------------------------------------------------------------

def test_limit_fn_trades_tightness_never_exactness():
    from repro.core import dtw_pairs

    ds, idx, cfg0 = _setup(k=2)
    q = jnp.asarray(ds.x_test)
    for lim in (1, 3, 1000):
        plan = dataclasses.replace(
            default_plan(cfg0.cascade),
            compaction=Compaction(
                budget=8,
                limit_fn=lambda lb01, B, k, _l=lim: jnp.full(
                    (lb01.shape[0],), _l, jnp.int32),
            ),
        )
        res = nn_search(idx, ds.x_test, cfg0, plan=plan)
        bd, _ = brute_force(idx, ds.x_test, 8, k=2)
        np.testing.assert_array_equal(np.array(res.dists), np.array(bd))
        # bounds stay valid lower bounds whatever the allocation
        dm = np.array(dtw_pairs(q, idx.series, cfg0.cascade.w))
        assert np.all(np.array(res.lb) <= dm * (1 + 1e-4) + 1e-4)


# ---------------------------------------------------------------------------
# adaptive-budget memo keys on (index, k, w)
# ---------------------------------------------------------------------------

def test_limit_fn_with_pre_liveness_custom_tier():
    """A custom pairwise tier written to the old contract (no ``live``
    kwarg) keeps working under a limit_fn compaction: the executor gives
    it the maskless call and applies the slot mask itself."""
    ds, idx, cfg0 = _setup(k=2)

    def old_style_fn(qrows, crows, urows, lrows, cfg):   # no live kwarg
        from repro.kernels.ref import lb_enhanced_pairwise_ref
        return lb_enhanced_pairwise_ref(qrows, crows, urows, lrows,
                                        cfg.w, cfg.v)

    tier = BoundTier("old_pairwise", cost="O(L)", scope="pairwise",
                     fn=old_style_fn)
    plan = dataclasses.replace(
        default_plan(cfg0.cascade),
        tiers=(*default_plan(cfg0.cascade).all_pairs_tiers, tier),
        compaction=Compaction(
            budget=8,
            limit_fn=lambda lb01, B, k: jnp.full(
                (lb01.shape[0],), 3, jnp.int32),
        ),
    )
    res = nn_search(idx, ds.x_test, cfg0, plan=plan)
    bd, _ = brute_force(idx, ds.x_test, 8, k=2)
    np.testing.assert_array_equal(np.array(res.dists), np.array(bd))


def test_budget_memo_keys_on_index_k_w(monkeypatch):
    """A bucket estimated for k=1 must not be reused for k=3 (tau grows
    with k), nor across windows or stores."""
    calls = []
    from repro.search import cascade as casc

    orig = casc.choose_survivor_budget

    def counting(q, index, cfg, k=1, **kw):
        calls.append((id(index.series), cfg.w, k))
        return orig(q, index, cfg, k, **kw)

    monkeypatch.setattr(casc, "choose_survivor_budget", counting)
    pl.budget_cache_clear()

    ds, idx, _ = _setup(w=8)
    for k in (1, 3):
        cfg = EngineConfig(cascade=CascadeConfig(w=8), verify_chunk=4, k=k)
        nn_search(idx, ds.x_test, cfg)
        nn_search(idx, ds.x_test, cfg)          # second call: memo hit
    assert [c[2] for c in calls] == [1, 3]      # one estimate per k
    assert pl.budget_cache_len() == 2

    # a different window re-estimates on the same store
    idx12 = build_index(ds.x_train, 12, ds.y_train)
    cfg12 = EngineConfig(cascade=CascadeConfig(w=12), verify_chunk=4, k=1)
    nn_search(idx12, ds.x_test, cfg12)
    assert calls[-1][1] == 12 and len(calls) == 3
    pl.budget_cache_clear()
