"""Cutoff-aware (PrunedDTW-style) DTW semantics + staged-cascade invariants.

The contract under test (kernels/dtw_band.py, core/dtw.py):
  * cutoff-aware DTW equals plain DTW whenever the true distance is below
    the cutoff;
  * otherwise it returns a value >= cutoff (normally +inf — the lane
    abandoned);
  * the band-packed Pallas kernel matches the jnp reference bit-for-bit on
    the abandon decision (both poison on the same per-anti-diagonal
    frontier);
and for the staged cascade (search/cascade.py, search/engine.py):
  * staged bounds never exceed true DTW;
  * the engine stays exact with staging on, off, and under tiny survivor
    budgets;
  * per-query n_dtw with the staged cascade never exceeds the dense-tier
    engine's count.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dtw_pairs, oracle
from repro.core.dtw import dtw
from repro.data import make_dataset
from repro.kernels import ops, ref
from repro.search import (
    CascadeConfig,
    EngineConfig,
    brute_force,
    build_index,
    compute_bounds,
    nn_search,
    staged_bounds,
)


# ---------------------------------------------------------------------------
# cutoff semantics on the scalar jnp path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,w", [(8, 2), (16, 0), (33, 7), (64, 20), (16, 16)])
def test_dtw_cutoff_exact_below(rng, L, w):
    a = jnp.array(rng.normal(size=L).astype(np.float32))
    b = jnp.array(rng.normal(size=L).astype(np.float32))
    want = float(dtw(a, b, w))
    got = float(dtw(a, b, w, want * 2.0 + 1.0))
    assert np.allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("L,w", [(8, 2), (16, 0), (33, 7), (64, 20)])
def test_dtw_cutoff_abandons_above(rng, L, w):
    a = jnp.array(rng.normal(size=L).astype(np.float32))
    b = jnp.array(rng.normal(size=L).astype(np.float32))
    want = float(dtw(a, b, w))
    cut = want * 0.5
    got = float(dtw(a, b, w, cut))
    assert got >= cut - 1e-6          # usually +inf; never a value below cut


def test_dtw_cutoff_inf_is_noop(rng):
    a = jnp.array(rng.normal(size=24).astype(np.float32))
    b = jnp.array(rng.normal(size=24).astype(np.float32))
    assert float(dtw(a, b, 5, jnp.inf)) == pytest.approx(float(dtw(a, b, 5)))


def test_dtw_band_packed_matches_oracle(rng):
    """The O(L*W) band-packed recurrence is still the paper's Eq. 1-2."""
    for L, w in [(8, 2), (16, 0), (16, 16), (33, 7), (64, 20), (5, 1)]:
        a = rng.normal(size=L).astype(np.float32)
        b = rng.normal(size=L).astype(np.float32)
        assert np.allclose(
            float(dtw(jnp.array(a), jnp.array(b), w)),
            oracle.dtw(a, b, w), rtol=1e-4,
        )


# ---------------------------------------------------------------------------
# band-packed Pallas kernel vs the jnp reference
# ---------------------------------------------------------------------------

# odd lengths, w in {0, 1, L//4, L}, and P off the 8-sublane/tile multiple
KERNEL_SWEEP = [
    (9, 33, 0), (9, 33, 1), (9, 33, 8), (9, 33, 33),
    (130, 47, 11), (1, 16, 4), (5, 64, 16), (12, 21, 21),
]


@pytest.mark.parametrize("P,L,w", KERNEL_SWEEP)
def test_dtw_band_kernel_cutoff_sweep(rng, P, L, w):
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    want_plain = np.array(ref.dtw_band_ref(a, b, w))
    got_plain = np.array(ops.dtw_band_op(a, b, w))
    np.testing.assert_allclose(got_plain, want_plain, rtol=1e-4, atol=1e-5)
    # alternating low/high cutoffs, away from the abandon decision boundary
    cut = jnp.array(np.where(np.arange(P) % 2 == 0,
                             want_plain * 0.5,
                             want_plain * 2.0 + 1.0).astype(np.float32))
    got = np.array(ops.dtw_band_op(a, b, w, cut))
    want = np.array(ref.dtw_band_ref(a, b, w, cut))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # high-cutoff lanes are exact
    np.testing.assert_allclose(got[1::2], want_plain[1::2], rtol=1e-4,
                               atol=1e-5)
    # low-cutoff lanes never report below their cutoff
    assert np.all(got[0::2] >= np.array(cut)[0::2] - 1e-5)


# ---------------------------------------------------------------------------
# row-block early-exit grid (PR 2): skipped blocks never change results
# ---------------------------------------------------------------------------

# shapes hit multi-tile P, odd L, short last blocks, and R > D
EARLY_EXIT_SWEEP = [
    (9, 33, 8, 8), (130, 47, 11, 16), (5, 64, 16, 64), (12, 21, 5, 7),
    (8, 40, 10, 200),
]


@pytest.mark.parametrize("P,L,w,R", EARLY_EXIT_SWEEP)
def test_dtw_band_early_exit_matches_ref_and_legacy(rng, P, L, w, R):
    from repro.kernels.dtw_band import dtw_band_pallas
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut = jnp.array(np.where(np.arange(P) % 2 == 0,
                             plain * 0.5,
                             plain * 2.0 + 1.0).astype(np.float32))
    got = np.array(dtw_band_pallas(a, b, w, cut, row_block=R, interpret=True))
    want = np.array(ref.dtw_band_ref(a, b, w, cut, row_block=R))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # the legacy per-step-poisoning kernel abandons the same lanes
    legacy = np.array(dtw_band_pallas(a, b, w, cut, early_exit=False,
                                      interpret=True))
    np.testing.assert_allclose(got, legacy, rtol=1e-4, atol=1e-5)
    # pairs whose true distance beats their cutoff stay exact even when
    # other lanes in their tile are poisoned (skipping is tile-level)
    np.testing.assert_allclose(got[1::2], plain[1::2], rtol=1e-4, atol=1e-5)
    assert np.all(got[0::2] >= np.array(cut)[0::2] - 1e-5)


def test_dtw_band_early_exit_lone_survivor(rng):
    """A single surviving lane keeps its whole tile alive: no block may be
    skipped while any lane still needs it."""
    from repro.kernels.dtw_band import dtw_band_pallas
    P, L, w = 16, 48, 12
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut_np = (plain * 1e-3).astype(np.float32)     # everyone abandons...
    cut_np[7] = np.inf                             # ...except lane 7
    cut = jnp.array(cut_np)
    got = np.array(dtw_band_pallas(a, b, w, cut, row_block=8, interpret=True))
    np.testing.assert_allclose(got[7], plain[7], rtol=1e-4, atol=1e-5)
    assert np.all(np.isinf(np.delete(got, 7)))


def test_dtw_band_early_exit_all_dead_tile(rng):
    """A fully-poisoned tile returns +inf for every lane (the skipped
    blocks' output path)."""
    from repro.kernels.dtw_band import dtw_band_pallas
    P, L, w = 8, 64, 16
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    plain = np.array(ref.dtw_band_ref(a, b, w))
    cut = jnp.array((plain * 1e-3).astype(np.float32))
    got = np.array(dtw_band_pallas(a, b, w, cut, row_block=16, interpret=True))
    assert np.all(np.isinf(got))
    want = np.array(ref.dtw_band_ref(a, b, w, cut, row_block=16))
    np.testing.assert_allclose(got, want)


def test_dtw_band_early_exit_nocut_matches_plain(rng):
    """Without a cutoff the row-block grid is the plain banded DTW."""
    from repro.kernels.dtw_band import dtw_band_pallas
    P, L, w = 9, 33, 8
    a = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(P, L)).astype(np.float32))
    got = np.array(dtw_band_pallas(a, b, w, interpret=True, row_block=8))
    want = np.array(ref.dtw_band_ref(a, b, w))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_dtw_band_kernel_long_series_streams(rng):
    """L beyond the residency crossover routes to the streaming kernel
    (there is no length ceiling any more) with cutoff semantics intact."""
    L = ops._DTW_RESIDENT_MAX_L + 7
    a = jnp.array(rng.normal(size=(2, L)).astype(np.float32))
    b = jnp.array(rng.normal(size=(2, L)).astype(np.float32))
    out = ops.dtw_band_op(a, b, 3, jnp.array([np.inf, 0.0], np.float32))
    assert out.shape == (2,)
    assert np.isfinite(float(out[0])) and float(out[1]) == np.inf


# ---------------------------------------------------------------------------
# staged cascade + engine invariants
# ---------------------------------------------------------------------------

def _setup(w=8, n_per=12, L=48, seed=0, k=1, chunk=16, verify=4, **ckw):
    ds = make_dataset(n_classes=3, n_train_per_class=n_per,
                      n_test_per_class=4, length=L, seed=seed)
    idx = build_index(ds.x_train, w, ds.y_train)
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, candidate_chunk=chunk, **ckw),
        verify_chunk=verify, k=k,
    )
    return ds, idx, cfg


def test_staged_bounds_below_true_distance():
    ds, idx, cfg = _setup()
    res = staged_bounds(jnp.asarray(ds.x_test), idx, cfg.cascade, k=2)
    dm = np.array(dtw_pairs(jnp.asarray(ds.x_test), idx.series, cfg.cascade.w))
    assert np.all(np.array(res.lb) <= dm * (1 + 1e-4) + 1e-4)
    # seed distances are the true distances of the seeded pairs
    qi = np.arange(dm.shape[0])[:, None]
    np.testing.assert_allclose(
        np.array(res.seed_d), dm[qi, np.array(res.seed_idx)], rtol=1e-4,
        atol=1e-5,
    )


def test_staged_matches_dense_bounds_on_survivors():
    """Within the compacted set the staged bound equals the dense tier-2."""
    ds, idx, cfg = _setup(w=8)
    q = jnp.asarray(ds.x_test)
    dense = np.array(compute_bounds(q, idx, CascadeConfig(w=8, staged=False)))
    staged = np.array(compute_bounds(q, idx, CascadeConfig(w=8)))
    # budget >= N here, so every non-seed entry matches the dense tiers and
    # seed entries may only be tighter (exact DTW)
    assert np.all(staged >= dense - 1e-5)


@pytest.mark.parametrize("w,k,verify,seed", [
    (8, 1, 4, 0), (0, 2, 3, 1), (24, 3, 1, 2), (4, 1, 9, 3), (16, 2, 5, 4),
])
def test_staged_engine_exact_and_no_more_dtw(w, k, verify, seed):
    ds, idx, cfg = _setup(w=w, seed=seed, k=k, verify=verify)
    res = nn_search(idx, ds.x_test, cfg)
    bd, _ = brute_force(idx, ds.x_test, w, k=k)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-4, atol=1e-5)
    cfg_dense = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, candidate_chunk=16, staged=False),
        verify_chunk=verify, k=k,
    )
    res_dense = nn_search(idx, ds.x_test, cfg_dense)
    np.testing.assert_allclose(np.array(res_dense.dists), np.array(bd),
                               rtol=1e-4, atol=1e-5)
    assert np.all(np.array(res.n_dtw) <= np.array(res_dense.n_dtw))
    assert np.all(np.array(res.n_dtw) >= 1)


def test_tiny_survivor_budget_stays_exact():
    """The budget only trades bound tightness for tier-2 work — never
    exactness."""
    ds, idx, _ = _setup()
    for budget in (1, 2, 5):
        cfg = EngineConfig(
            cascade=CascadeConfig(w=8, survivor_budget=budget),
            verify_chunk=4, k=2,
        )
        res = nn_search(idx, ds.x_test, cfg)
        bd, _ = brute_force(idx, ds.x_test, 8, k=2)
        np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                                   rtol=1e-4, atol=1e-5)


def test_staged_engine_with_exclude():
    ds, idx, cfg = _setup()
    q = ds.x_train[:6]
    res = nn_search(idx, q, cfg, exclude=jnp.arange(6))
    assert np.all(np.array(res.idx[:, 0]) != np.arange(6))
    bd, bi = brute_force(idx, q, 8, k=1, exclude=jnp.arange(6))
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# adaptive bucketed survivor budget
# ---------------------------------------------------------------------------

def test_adaptive_budget_is_bucketed_and_exact():
    from repro.search import choose_survivor_budget
    ds, idx, _ = _setup()
    cfg = EngineConfig(
        cascade=CascadeConfig(w=8, adaptive_budget=True), verify_chunk=4, k=2,
    )
    b = choose_survivor_budget(ds.x_test, idx, cfg.cascade, k=2)
    # clamped to n, or a power-of-two bucket >= 64: recompiles stay bounded
    assert b == idx.n or (b >= 64 and (b & (b - 1)) == 0)
    res = nn_search(idx, ds.x_test, cfg)
    bd, _ = brute_force(idx, ds.x_test, 8, k=2)
    np.testing.assert_allclose(np.array(res.dists), np.array(bd),
                               rtol=1e-4, atol=1e-5)


def test_static_budget_rule_is_bucketed():
    """With survivor_budget=None the static rule emits power-of-two buckets
    (clamped to n), never arbitrary N/8 widths."""
    cfg = CascadeConfig(w=8)
    for n, k in [(36, 1), (1000, 3), (5000, 1), (100000, 5), (63, 2)]:
        b = cfg.budget(n, k)
        assert b == n or (b >= 64 and (b & (b - 1)) == 0)
        assert b <= n
    # explicit budgets pass through un-bucketed (tests rely on tiny budgets)
    assert CascadeConfig(w=8, survivor_budget=5).budget(1000) == 5


# ---------------------------------------------------------------------------
# chunked brute force
# ---------------------------------------------------------------------------

def test_brute_force_chunking_invariant():
    """Any candidate chunking gives identical distances (bounded memory)."""
    ds, idx, _ = _setup()
    want_d, want_i = brute_force(idx, ds.x_test, 8, k=3, chunk=idx.n)
    for chunk in (1, 7, 16, 1000):
        got_d, got_i = brute_force(idx, ds.x_test, 8, k=3, chunk=chunk)
        np.testing.assert_allclose(np.array(got_d), np.array(want_d),
                                   rtol=1e-5)


def test_brute_force_chunked_exclude():
    ds, idx, _ = _setup()
    q = ds.x_train[:5]
    d, i = brute_force(idx, q, 8, k=1, exclude=jnp.arange(5), chunk=4)
    assert np.all(np.array(i[:, 0]) != np.arange(5))
