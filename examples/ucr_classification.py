"""End-to-end driver: NN-DTW time-series classification with the
LB_ENHANCED cascade (the paper's headline application, SS IV-B).

Builds a UCR-like dataset, indexes the training set, classifies the test
set with the tiered cascade + exact verification, and reports accuracy,
pruning power and timing vs the unpruned brute force.

Run: PYTHONPATH=src python examples/ucr_classification.py [--window 0.2]
"""

import argparse
import time

import jax
import jax.numpy as jnp  # noqa: F401
import numpy as np

from repro.data import make_dataset
from repro.search import (
    CascadeConfig,
    EngineConfig,
    brute_force,
    build_index,
    classify,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--window", type=float, default=0.2)
    ap.add_argument("--v", type=int, default=4)
    ap.add_argument("--length", type=int, default=64)
    ap.add_argument("--per-class", type=int, default=200,
                    help="the paper's regime is large N — pruning pays "
                         "off as the store grows")
    ap.add_argument("--n-test", type=int, default=4)
    args = ap.parse_args()

    ds = make_dataset(
        n_classes=5, n_train_per_class=args.per_class,
        n_test_per_class=args.n_test, length=args.length, seed=7,
    )
    w = max(1, int(args.window * ds.length))
    print(f"dataset: {ds.x_train.shape[0]} train / {ds.x_test.shape[0]} test, "
          f"L={ds.length}, W={w}, V={args.v}")

    idx = build_index(ds.x_train, w, ds.y_train)
    # use_pallas=False: on this CPU container the Pallas kernels run in
    # interpret mode (semantics-only); the jnp path gives honest wall-clock.
    cfg = EngineConfig(cascade=CascadeConfig(w=w, v=args.v, use_pallas=False),
                       verify_chunk=64, k=1)

    # jit + warm up both paths; report steady-state step time
    from repro.search import nn_search
    cascade_fn = jax.jit(lambda qq: nn_search(idx, qq, cfg).dists)
    brute_fn = jax.jit(
        lambda qq: brute_force(idx, qq, w, k=1, use_pallas=False)[0]
    )
    qj = jnp.asarray(ds.x_test)
    jax.block_until_ready(cascade_fn(qj))
    jax.block_until_ready(brute_fn(qj))

    t0 = time.perf_counter()
    jax.block_until_ready(cascade_fn(qj))
    t_cascade = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(brute_fn(qj))
    t_brute = time.perf_counter() - t0

    pred, res = classify(idx, ds.x_test, cfg)
    bd, _ = brute_force(idx, ds.x_test, w, k=1, use_pallas=False)

    acc = float(np.mean(np.array(pred) == ds.y_test))
    prune = float(np.mean(np.array(res.pruning_power())))
    assert np.allclose(np.array(res.dists), np.array(bd), rtol=1e-4), \
        "cascade changed the NN result!"

    print(f"accuracy          : {acc:.1%}")
    print(f"pruning power     : {prune:.1%} of DTW computations skipped")
    print(f"mean DTW verified : {float(np.mean(np.asarray(res.n_dtw))):.1f} "
          f"of {idx.n} candidates")
    print(f"cascade time      : {t_cascade:.2f}s   brute force: {t_brute:.2f}s "
          f"({t_brute / t_cascade:.1f}x speedup, identical results)")


if __name__ == "__main__":
    main()
