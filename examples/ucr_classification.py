"""End-to-end driver: NN-DTW time-series classification with the
LB_ENHANCED cascade (the paper's headline application, SS IV-B).

Builds a UCR-like dataset, indexes the training set *with store-level
plan calibration* (the planner prices every tier on a sample of the
store and commits the optimised verification plan — search/planner.py),
classifies the test set with the committed plan + exact verification,
and reports accuracy, the paper's Fig.-style per-tier pruning-power
table, and timing vs the unpruned brute force.

Run: PYTHONPATH=src python examples/ucr_classification.py [--window 0.2]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_dataset
from repro.search import (
    CascadeConfig,
    EngineConfig,
    brute_force,
    build_index,
    default_plan,
    nn_search,
)
from repro.search import planner as plr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--window", type=float, default=0.2)
    ap.add_argument("--v", type=int, default=4)
    ap.add_argument("--length", type=int, default=64)
    ap.add_argument("--per-class", type=int, default=200,
                    help="the paper's regime is large N — pruning pays "
                         "off as the store grows")
    ap.add_argument("--n-test", type=int, default=4)
    args = ap.parse_args()

    ds = make_dataset(
        n_classes=5, n_train_per_class=args.per_class,
        n_test_per_class=args.n_test, length=args.length, seed=7,
    )
    w = max(1, int(args.window * ds.length))
    print(f"dataset: {ds.x_train.shape[0]} train / {ds.x_test.shape[0]} test, "
          f"L={ds.length}, W={w}, V={args.v}")

    # use_pallas=False: on this CPU container the Pallas kernels run in
    # interpret mode (semantics-only); the jnp path gives honest wall-clock.
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=args.v, use_pallas=False),
        verify_chunk=64, k=1, auto_plan=True,
    )
    # store-level calibration: the planner prices the default plan on a
    # sample of the store itself and commits the optimised plan, so the
    # first real query batch below starts warm
    idx = build_index(ds.x_train, w, ds.y_train, calibrate=cfg)
    decision = plr.lookup_plan(idx, cfg.cascade, cfg.k,
                               default_plan(cfg.cascade))
    print(f"committed plan    : {decision.summary()}")

    # search the test set under the committed plan, with the pruning report
    res, stats = nn_search(idx, ds.x_test, cfg, with_stats=True)
    votes = idx.labels[res.idx]                                    # (Q, k)
    pred = np.array(votes[:, 0])

    # jit + warm up both paths (the committed plan pinned explicitly —
    # calibration is host-side, so a traced search runs the plan it is
    # given); report steady-state step time
    cascade_fn = jax.jit(
        lambda qq: nn_search(idx, qq, cfg, plan=decision.plan).dists
    )
    brute_fn = jax.jit(
        lambda qq: brute_force(idx, qq, w, k=1, use_pallas=False)[0]
    )
    qj = jnp.asarray(ds.x_test)
    jax.block_until_ready(cascade_fn(qj))
    jax.block_until_ready(brute_fn(qj))

    t0 = time.perf_counter()
    jax.block_until_ready(cascade_fn(qj))
    t_cascade = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(brute_fn(qj))
    t_brute = time.perf_counter() - t0

    bd, _ = brute_force(idx, ds.x_test, w, k=1, use_pallas=False)
    acc = float(np.mean(pred == ds.y_test))
    prune = float(np.mean(np.array(res.pruning_power())))
    assert np.allclose(np.array(res.dists), np.array(bd), rtol=1e-4), \
        "cascade changed the NN result!"

    print()
    print(stats.table())       # the paper's pruning-power readout, per tier
    print()
    print(f"accuracy          : {acc:.1%}")
    print(f"pruning power     : {prune:.1%} of DTW computations skipped")
    print(f"mean DTW verified : {float(np.mean(np.asarray(res.n_dtw))):.1f} "
          f"of {idx.n} candidates")
    print(f"cascade time      : {t_cascade:.2f}s   brute force: {t_brute:.2f}s "
          f"({t_brute / t_cascade:.1f}x speedup, identical results)")
    # the default-on exactness guards (search/guards.py): admissibility /
    # conservation / accounting counters for this search, plus whether
    # the degradation ladder had to serve a brute-force fallback
    if stats.guards is not None:
        verdict = "tripped: " + ", ".join(stats.guards.tripped()) \
            if stats.guards.tripped() else "all clear"
        print(f"exactness guards  : {verdict}"
              + ("   [DEGRADED]" if stats.degraded else ""))
        print(f"                    {stats.guards.summary()}")


if __name__ == "__main__":
    main()
