"""Distributed NN-DTW search over a (data, model) mesh.

Emulates an 8-device pod slice with host devices (the production 16x16 and
2x16x16 meshes use the identical code path — see launch/dryrun.py --paper).
The candidate store is sharded over 'data', queries over 'model'; each
device runs the local tier pipeline — with the *global survivor budget*
(the default: per-shard compaction limits allocated in proportion to
all-gathered tier-0/1 survivor mass, see search/distributed.py) — and the
per-query top-k merges with one all_gather.

Run: python examples/distributed_search.py   (sets XLA_FLAGS itself)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.data import make_dataset  # noqa: E402
from repro.search import (  # noqa: E402
    CascadeConfig,
    EngineConfig,
    brute_force,
    build_index,
    make_distributed_search,
    shard_index,
)


def main() -> None:
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.size} devices")

    ds = make_dataset(n_classes=4, n_train_per_class=64, n_test_per_class=8,
                      length=128, seed=13)
    w = int(0.2 * ds.length)
    idx = build_index(ds.x_train, w, ds.y_train)
    cfg = EngineConfig(
        cascade=CascadeConfig(w=w, v=4, use_pallas=False),
        verify_chunk=16, k=3,
    )
    sidx = shard_index(mesh, idx, ("data",))
    # NOTE: not jax.jit-wrapped.  On jax 0.4.x, jit(shard_map(...)) around
    # the engine's data-dependent while_loop miscompiles (verified against
    # brute force; see search/distributed.py docstring) — the shard_map
    # alone is already exact and parallel.
    step = make_distributed_search(mesh, cfg, data_axes=("data",),
                                   query_axis="model")

    q = jnp.asarray(ds.x_test)
    t0 = time.perf_counter()
    d, i, n_dtw = step(sidx.series, sidx.labels, sidx.upper, sidx.lower,
                       sidx.kim, sidx.kim_ok, q)
    jax.block_until_ready(d)
    dt = time.perf_counter() - t0

    bd, _ = brute_force(idx, ds.x_test, w, k=3, use_pallas=False)
    exact = np.allclose(np.array(d), np.array(bd), rtol=1e-4)
    print(f"3-NN over {idx.n} candidates x {q.shape[0]} queries: {dt:.2f}s")
    print(f"exact vs single-device brute force: {exact}")
    print(f"mean DTW verified per query (all shards): "
          f"{float(np.mean(np.asarray(n_dtw))):.1f} / {idx.n}")
    votes = np.array(idx.labels)[np.array(i)]
    pred = np.apply_along_axis(lambda r: np.bincount(r).argmax(), 1, votes)
    print(f"accuracy: {float(np.mean(pred == ds.y_test)):.1%}")


if __name__ == "__main__":
    main()
