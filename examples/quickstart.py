"""Quickstart: the paper's lower bounds on one pair of series.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    dtw,
    envelope,
    lb_enhanced,
    lb_improved,
    lb_keogh,
    lb_kim,
    lb_new,
)
from repro.data import random_pairs


def main() -> None:
    L = 128
    a_np, b_np = random_pairs(1, L, seed=42)
    a, b = jnp.asarray(a_np[0]), jnp.asarray(b_np[0])
    w = int(0.3 * L)                           # Sakoe-Chiba window

    d = float(dtw(a, b, w))
    print(f"DTW_w(A,B)         = {d:10.3f}   (squared cost, W={w})")
    print(f"{'bound':<18}{'value':>10}  tightness")
    for name, val in [
        ("LB_KIM", float(lb_kim(a, b))),
        ("LB_KEOGH", float(lb_keogh(a, b, w))),
        ("LB_IMPROVED", float(lb_improved(a, b, w))),
        ("LB_NEW", float(lb_new(a, b, w))),
        ("LB_ENHANCED^1", float(lb_enhanced(a, b, w, 1))),
        ("LB_ENHANCED^4", float(lb_enhanced(a, b, w, 4))),
        ("LB_ENHANCED^8", float(lb_enhanced(a, b, w, 8))),
    ]:
        assert val <= d * (1 + 1e-4), "lower bound exceeded DTW!"
        print(f"{name:<18}{val:>10.3f}  {val / d:8.3f}")

    u, lo = envelope(b, w)
    inside = float(jnp.mean((a >= lo) & (a <= u)))
    print(f"\nquery points inside B's envelope: {inside:.0%} "
          f"(these contribute 0 to LB_KEOGH — the elastic bands still "
          f"extract cost from the first/last {4} positions)")


if __name__ == "__main__":
    main()
