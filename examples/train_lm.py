"""Train a ~100M-parameter LM for a few hundred steps on synthetic data.

This drives the full production path — scanned blocks, remat, chunked CE,
AdamW, checkpointing — at a laptop-friendly size (the same ``--arch``
switch scales to the full assigned configs under the pod mesh).

Run: PYTHONPATH=src python examples/train_lm.py --steps 200
(~100M params; pass --tiny for a quick smoke run)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.tokens import TokenPipeline
from repro.models.model import LM
from repro.train import OptConfig, init_state, make_train_step, save_checkpoint

CFG_100M = ArchConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=32768,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = CFG_100M
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=256,
                                  vocab=1024, n_heads=4, n_kv_heads=2)
        args.steps = min(args.steps, 20)
        args.seq = 64

    model = LM(cfg=cfg, mesh=None)
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(model.init, jax.random.PRNGKey(0))
        )
    )
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    opt = OptConfig(lr=3e-4, warmup=20)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq, seed=0)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, m = step(state, batch)
        if (i + 1) % 10 == 0 or i == 0:
            print(f"step {i + 1:4d}  loss {float(m['loss']):.4f}  "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, state,
                        extra={"pipeline": pipe.state()})
        print(f"checkpoint written to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
